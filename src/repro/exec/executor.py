"""The dataflow executor — runs a :class:`CompiledDesign` end to end.

Execution model (synchronous dataflow, one sweep ≈ one pipeline clock):

* Every task fires ``iterations`` times.  A task may fire in a sweep when
  every in-channel (back edges included — those carry the iteration
  dependency and are seeded by ``ProgramBinding.prime``) has a *visible*
  token and every out-channel has a free slot.
* Tasks are processed in **reverse topological order** within a sweep, so a
  consumer's pop frees its FIFO slot before the producer's push is
  considered — the software equivalent of simultaneous push+pop on a full
  hardware FIFO.  Tokens pushed in sweep *t* become visible at
  ``t + latency``, so data still advances at most one task per sweep.
* Channel capacity comes from the §4.6 balanced ``depth`` on the graph
  channel; channel latency from the pipeline report's ``added_latency``.
  With balanced depths every task fires every sweep once the pipeline fills
  (full throughput); clamp a depth below ``added + slack + 1`` and the
  reconvergent join starves — which the detector below reports instead of
  silently throttling.

Network fabric (``repro.net``): when the design (or the caller) supplies a
:class:`~repro.net.fabric.Fabric`, inter-device pushes are packetized into
flits and routed over the physical links by a
:class:`~repro.net.transport.FabricTransport` stepped once per sweep —
channels sharing a link contend for its bandwidth, credits backpressure the
hops, and a token only becomes visible after its own message delivers.
``fabric=None`` forces the ideal point-to-point ``jax.device_put`` path
(the pre-fabric behaviour, bit-identical numerics).  After the last firing
the network is drained so the per-link byte accounting is complete.

HBM banks (``repro.mem``): when the binding declares ``mem_reads`` streams
and the design (or the caller) supplies a
:class:`~repro.mem.banks.MemConfig`, each stream becomes an
:class:`~repro.mem.channels.AsyncMemChannel` against a
:class:`~repro.mem.banks.MemorySystem` stepped once per sweep — the
``async_mmap`` split request/response contract: requests are pumped ahead
of consumption up to the credit bound, banks serve bursts fairly across
the channels mapped to them, and a task additionally waits on its head
memory response before firing (tallied in ``mem_waits``).  ``mem=None``
forces the ideal memory path: every response ready the sweep it is issued,
bit-identical numerics (payloads come from the binding either way).

Detection:

* **Hard deadlock** — a sweep fires nothing, and no queued token will ever
  become visible (tokens still transiting the fabric count as in flight).
  Raises :class:`DeadlockError` listing each unfinished task with the
  channel that blocks it.
* **FIFO starvation** — a join cannot fire because one in-channel is empty
  while a sibling in-channel sits *at capacity*: the signature of an
  unbalanced cut-set (§4.6).  Transient during pipeline fill never matches
  (balanced depths leave headroom); persistent imbalance accumulates events
  until ``starve_limit`` trips :class:`StarvationError` with the channel
  that needs more depth.  When the starved input still has tokens in the
  network, the wait is *congestion*, not imbalance — it is tallied in
  ``congestion_waits`` instead of tripping the detector.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence

import jax

from ..compiler.artifact import CompiledDesign
from .channels import FifoChannel
from .programs import (SOURCE_KEY, ProgramBinding, RoutedOutput,
                       bind_programs)
from .report import ExecutionReport, build_report


class DeadlockError(RuntimeError):
    """No task can ever fire again, yet the run is incomplete."""


class StarvationError(DeadlockError):
    """A join repeatedly starves behind an unbalanced FIFO (§4.6)."""


#: Sentinel for ``execute(fabric=...)``: use the design's fabric (pass
#: ``fabric=None`` explicitly to force the ideal transfer path).
FROM_DESIGN = object()


@dataclasses.dataclass
class ExecutionResult:
    """What came out of the pipe, plus the measured execution report."""

    outputs: Any                          # binding.finalize(...) result
    sink_outputs: Dict[str, List[Any]]    # raw per-firing sink values
    report: ExecutionReport


def _physical_devices(num_logical: int, devices=None) -> List[Any]:
    """Map logical partition devices onto the physical jax devices.

    CI runs host-platform emulation (``--xla_force_host_platform_device_count``)
    so logical == physical; a bare interpreter with one CPU device still
    executes every design correctly — logical placement keeps driving the
    traffic accounting, physical arrays just share the one device.
    """
    phys = list(devices) if devices is not None else list(jax.devices())
    return [phys[d % len(phys)] for d in range(max(1, num_logical))]


def _block(token: Any) -> None:
    for leaf in jax.tree_util.tree_leaves(token):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def _estimate_flit_hops(channels: Sequence[FifoChannel], transport) -> int:
    """Modeled flit-hops one full iteration pushes into the network (the
    sweep-bound heuristic; actual token sizes may exceed the model, so the
    caller pads generously)."""
    total = 0
    for fc in channels:
        if not fc.inter_device:
            continue
        gch = fc.graph_channel
        nbytes = max(gch.bytes_per_step or 0.0, gch.width_bits / 8.0, 1.0)
        total += (transport.config.flits_for(int(nbytes))
                  * len(transport.fabric.route(fc.src_dev, fc.dst_dev)))
    return total


def execute(design: CompiledDesign,
            binding: Optional[ProgramBinding] = None, *,
            inputs: Optional[Mapping[str, Any]] = None,
            devices: Optional[Sequence[Any]] = None,
            max_sweeps: Optional[int] = None,
            starve_limit: int = 3,
            check_starvation: bool = True,
            fabric: Any = FROM_DESIGN,
            net_config=None,
            mem: Any = FROM_DESIGN) -> ExecutionResult:
    """Run ``design`` as a multi-device dataflow program.

    ``binding`` defaults to the app hook resolved from the graph's name
    (``bind_programs(design.graph, inputs)``); ``inputs`` is that hook's
    numeric spec (shapes / iteration counts / seeds).  ``devices`` overrides
    the physical jax devices backing the partition's logical devices.
    ``fabric`` defaults to the design's fabric (``CompileOptions.fabric``);
    pass ``fabric=None`` to force the ideal transfer path or a
    :class:`~repro.net.fabric.Fabric` to override.  ``net_config`` is the
    :class:`~repro.net.transport.NetConfig` for the fabric transport.
    ``mem`` defaults to the design's bank model (``CompileOptions.mem``);
    pass ``mem=None`` to force the ideal memory path or a
    :class:`~repro.mem.banks.MemConfig` to override.
    """
    if design.partition is None:
        raise ValueError("execute() needs a partitioned design "
                         "(run the partition pass)")
    if binding is None:
        binding = bind_programs(design.graph, inputs)
    graph, assign = design.graph, design.partition.assignment
    rep = design.pipeline_report
    phys = _physical_devices(design.partition.num_devices(), devices)

    if fabric is FROM_DESIGN:
        fabric = design.fabric
    transport = None
    if fabric is not None:
        from ..net.transport import FabricTransport   # deferred: optional
        if fabric.num_devices != design.cluster.num_devices:
            raise ValueError(
                f"fabric spans {fabric.num_devices} devices but the "
                f"cluster has {design.cluster.num_devices}")
        transport = FabricTransport(fabric, net_config)

    channels: List[FifoChannel] = []
    for i, ch in enumerate(graph.channels):
        latency = 1 + (rep.added_latency.get(i, 0) if rep is not None else 0)
        channels.append(FifoChannel(
            i, ch, assign[ch.src], assign[ch.dst], latency=latency,
            dst_device=phys[assign[ch.dst] % len(phys)],
            transport=transport))
    for i, token in binding.prime.items():
        channels[i].prime(token)

    in_chs: Dict[str, List[FifoChannel]] = {t: [] for t in graph.tasks}
    out_chs: Dict[str, List[FifoChannel]] = {t: [] for t in graph.tasks}
    for fc in channels:
        if any(prev.src == fc.src for prev in in_chs[fc.dst]):
            # token_in is keyed by predecessor name — a second channel from
            # the same producer would silently overwrite the first's token.
            raise ValueError(
                f"parallel channels {fc.src}->{fc.dst}: the executor "
                "delivers one token per predecessor; merge the payloads "
                "into one channel (tokens are arbitrary pytrees)")
        in_chs[fc.dst].append(fc)
        out_chs[fc.src].append(fc)
    # Sinks: no forward (non-back) out-channel — their firing values are the
    # pipeline's results (back edges recirculate, they don't leave the pipe).
    sinks = [t for t in graph.tasks
             if not any(not fc.is_back for fc in out_chs[t])]

    T = binding.iterations

    # Async memory channels (repro.mem) — one per declared mem_reads stream,
    # placed on the task's logical device and its compiled (or default)
    # bank.  memsys=None (mem=None, or a design compiled without a bank
    # model) is the ideal path: same channels, immediate responses.
    mem_config = design.mem_config if mem is FROM_DESIGN else mem
    memsys = None
    mem_channels: List[Any] = []
    mem_chs: Dict[str, List[Any]] = {t: [] for t in graph.tasks}
    if binding.mem_reads:
        from ..mem.channels import AsyncMemChannel   # deferred: optional
        bank_map = dict(design.bank_map or {})
        if mem_config is not None:
            from ..mem.banks import MemorySystem
            from ..mem.contention import default_bank_map
            memsys = MemorySystem(design.partition.num_devices(), mem_config)
            if not bank_map:
                bank_map = default_bank_map(graph, assign, mem_config)
        for task in sorted(binding.mem_reads):
            for stream in sorted(binding.mem_reads[task]):
                mc = AsyncMemChannel(
                    len(mem_channels), task, stream,
                    binding.mem_reads[task][stream], T,
                    device=assign[task], bank=bank_map.get(task, 0),
                    memsys=memsys)
                mem_channels.append(mc)
                mem_chs[task].append(mc)

    order = list(reversed(graph.topo_order()))
    max_lat = max((fc.latency for fc in channels), default=1)
    if max_sweeps is None:
        # Pipeline depth is bounded by tasks × max latency; each of the T
        # firings advances at least one task per sweep barring throttling.
        max_sweeps = 64 + 4 * (T + len(graph.tasks)) * (1 + max_lat)
        if transport is not None:
            # The network serializes flits over shared links; transport
            # progress is guaranteed (>= 1 flit-hop per sweep while
            # active), so pad by a generous multiple of the modeled
            # per-iteration flit-hops (actual tokens may exceed the model).
            est = _estimate_flit_hops(channels, transport)
            max_sweeps += 256 + 64 * (T + 1) * max(1, est)
        if memsys is not None:
            # Banks serve >= 1 burst per sweep while queued, so the total
            # burst demand bounds the extra memory-induced sweeps.
            max_sweeps += 256 + 4 * sum(mc.total_bursts()
                                        for mc in mem_channels)

    fired: Dict[str, int] = {t: 0 for t in graph.tasks}
    starve_events: Dict[str, int] = {}
    starve_detail: List[Dict[str, Any]] = []
    congestion_waits: Dict[str, int] = {}
    mem_waits: Dict[str, int] = {}
    sink_outputs: Dict[str, List[Any]] = {t: [] for t in sinks}
    busy_s: Dict[int, float] = {}
    dev_fired: Dict[int, int] = {}

    def _blockers(task: str, sweep: int) -> List[str]:
        why = []
        for fc in in_chs[task]:
            if not fc.head_visible(sweep):
                why.append(f"input {fc.src}->{task} empty "
                           f"(occupancy {fc.occupancy}/{fc.capacity})")
        for fc in out_chs[task]:
            if fc.full:
                why.append(f"output {task}->{fc.dst} full "
                           f"(depth {fc.capacity})")
        for mc in mem_chs[task]:
            if mc.stats.consumed < mc.count and not mc.response_ready(sweep):
                why.append(f"memory {task}.{mc.stream} response pending "
                           f"({mc.stats.consumed}/{mc.count} consumed, "
                           f"{mc.outstanding} outstanding)")
        return why

    t_start = time.perf_counter()
    sweep, done = 0, False
    while sweep < max_sweeps:
        fired_this_sweep = 0
        for mc in mem_channels:
            # Issue reads ahead of consumption, up to the credit bound —
            # the multiple-outstanding-transactions loop of async_mmap.
            mc.pump(sweep)
        for v in order:
            if fired[v] >= T:
                continue
            ready = all(fc.head_visible(sweep) for fc in in_chs[v])
            space = all(not fc.full for fc in out_chs[v])
            if not (ready and space):
                if in_chs[v]:
                    empty = [fc for fc in in_chs[v]
                             if not fc.head_visible(sweep)]
                    at_cap = [fc for fc in in_chs[v] if fc.full]
                    if empty and at_cap:
                        if any(fc.in_flight > 0 for fc in empty):
                            # Data is coming — the wait is network
                            # congestion, not a §4.6 depth imbalance.
                            congestion_waits[v] = \
                                congestion_waits.get(v, 0) + 1
                            continue
                        # A bounded FIFO may transiently saturate while the
                        # pipeline fills (bounded by the paths' hop-count
                        # difference) — only persistence past starve_limit
                        # is the unbalanced-cut-set signature.
                        starve_events[v] = starve_events.get(v, 0) + 1
                        starve_detail.append({
                            "sweep": sweep, "task": v,
                            "starved_input": f"{empty[0].src}->{v}",
                            "full_input": f"{at_cap[0].src}->{v}",
                            "full_depth": at_cap[0].capacity})
                        if (check_starvation
                                and starve_events[v] >= starve_limit):
                            d = starve_detail[-1]
                            raise StarvationError(
                                f"join {v!r} starved {starve_events[v]}x on "
                                f"{d['starved_input']} while sibling FIFO "
                                f"{d['full_input']} sat full at depth "
                                f"{d['full_depth']}: unbalanced cut-set — "
                                f"§4.6 balancing would deepen "
                                f"{d['full_input']} (run the "
                                f"pipeline_interconnect pass or raise "
                                f"min_depth)")
                continue
            if mem_chs[v] and not all(mc.response_ready(sweep)
                                      for mc in mem_chs[v]):
                # The graph is ready but a memory response is still in the
                # bank pipe — read_data.empty() on the async_mmap side.
                mem_waits[v] = mem_waits.get(v, 0) + 1
                continue
            token_in: Dict[str, Any] = {fc.src: fc.pop(sweep)
                                        for fc in in_chs[v]}
            if not in_chs[v] and v in binding.source_inputs:
                token_in[SOURCE_KEY] = binding.source_inputs[v][fired[v]]
            for mc in mem_chs[v]:
                token_in[mc.stream] = mc.consume(sweep)
            dev = assign[v]
            t0 = time.perf_counter()
            out = binding.programs[v](token_in)
            _block(out)
            busy_s[dev] = busy_s.get(dev, 0.0) + time.perf_counter() - t0
            dev_fired[dev] = dev_fired.get(dev, 0) + 1
            if isinstance(out, RoutedOutput):
                for fc in out_chs[v]:
                    fc.push(out[fc.dst], sweep)
            else:
                for fc in out_chs[v]:
                    fc.push(out, sweep)
            if v in sinks:
                sink_outputs[v].append(out)
            fired[v] += 1
            fired_this_sweep += 1
        if transport is not None:
            for mid, ch_index in transport.step(sweep):
                channels[ch_index].on_delivered(mid, sweep)
        if memsys is not None:
            for rid, ch_index in memsys.step(sweep):
                mem_channels[ch_index].on_complete(rid, sweep)
        done = all(n >= T for n in fired.values())
        if done:
            break
        if fired_this_sweep == 0:
            # Tokens still ripening — or transiting the fabric — are
            # progress; a silent sweep without any is a cycle of blocked
            # tasks — diagnose it.
            ripening = any(vis > sweep for fc in channels
                           for vis in fc.pending_visibility())
            ripening = ripening or any(vis > sweep for mc in mem_channels
                                       for vis in mc.pending_visibility())
            in_network = transport is not None and transport.active
            in_memory = memsys is not None and memsys.active
            if not ripening and not in_network and not in_memory:
                lines = [f"  {t} ({fired[t]}/{T} firings): " +
                         ("; ".join(_blockers(t, sweep)) or "unknown")
                         for t in graph.tasks if fired[t] < T]
                raise DeadlockError(
                    "dataflow deadlock at sweep %d — no task can fire and "
                    "no token is in flight:\n%s" % (sweep, "\n".join(lines)))
        sweep += 1
    if not done:
        raise DeadlockError(
            f"executor exceeded max_sweeps={max_sweeps} "
            f"(fired {sum(fired.values())} of {T * len(graph.tasks)} "
            f"firings) — throughput collapse; check FIFO depths"
            + (" and fabric link budgets" if transport is not None else ""))

    if transport is not None and transport.active:
        # Run the network dry (e.g. final back-edge tokens nobody pops) so
        # the per-link byte conservation identities hold exactly.
        for mid, ch_index in transport.drain(sweep + 1):
            channels[ch_index].on_delivered(mid, sweep)
    if memsys is not None and memsys.active:
        # Every firing consumed its response, so the banks are normally dry
        # here — drain defensively so Σ bank bytes == Σ channel bytes holds
        # even if a program under-consumed.
        for rid, ch_index in memsys.drain(sweep + 1):
            mem_channels[ch_index].on_complete(rid, sweep)

    wall = time.perf_counter() - t_start
    report = build_report(
        design=design, channels=channels, iterations=T,
        sweeps=sweep + 1, wall_time_s=wall, device_busy_s=busy_s,
        device_fired=dev_fired, starvation_events=starve_events,
        starvation_detail=starve_detail, transport=transport,
        congestion_waits=congestion_waits, memsys=memsys,
        mem_channels=mem_channels, mem_waits=mem_waits)
    outputs = (binding.finalize(sink_outputs)
               if binding.finalize is not None else sink_outputs)
    return ExecutionResult(outputs=outputs, sink_outputs=sink_outputs,
                           report=report)
