"""repro.obs — sweep-granular tracing, metrics registry, critical path.

The observability contract, asserted end to end:

* the default ``NULL_TRACER`` is a no-op and a recording ``Tracer`` is
  **transparent** — traced runs are bit-identical to untraced runs on the
  exec, net, mem, tenant and chaos paths, with identical report counters;
* summed trace-event bytes reconcile with every legacy counter exactly
  (``assert_trace_report_consistent`` / ``assert_registry_consistent``);
* the exported Chrome trace-event JSON is structurally valid;
* the critical-path decomposition sums to the measured makespan exactly;
* the deprecated ``ExecutionReport`` field shims warn once and return the
  renamed fields' values.
"""
import warnings

import jax.numpy as jnp
import pytest

from repro.apps import APPS
from repro.compiler import CompileOptions, compile as tapa_compile
from repro.core import ResourceProfile, Task, TaskGraph, fpga_ring_cluster
from repro.exec import ProgramBinding, bind_programs, execute
from repro.mem import MemConfig
from repro.net import cluster_fabric
from repro.obs import (EVENT_FIELDS, NULL_TRACER, CritPath, MetricsRegistry,
                       Tracer, analyze, assert_registry_consistent,
                       assert_trace_report_consistent, coerce_tracer,
                       format_table, from_report, from_trace, makespan_row,
                       to_chrome_trace, validate_chrome_trace)
from repro.tenants import SLO, Tenant, TenantServer, bit_identical


def _counters(report):
    """Every counter the tracer must not perturb."""
    return {
        "sweeps": report.sweeps,
        "congestion_waits": dict(report.task_congestion_waits),
        "mem_waits": dict(report.task_mem_waits),
        "device_fired": dict(report.device_fired),
        "retransmit_bytes": report.net_retransmit_bytes_total,
        "link_bytes": ([int(l.bytes) for l in report.congestion.links]
                       if report.congestion is not None else []),
        "channel_bytes": [c.measured_bytes for c in report.channels],
    }


# ---------------------------------------------------------------------------
# Tracer mechanics.
# ---------------------------------------------------------------------------

def test_null_tracer_is_the_disabled_default():
    assert NULL_TRACER.enabled is False
    assert coerce_tracer(None) is NULL_TRACER
    t = Tracer()
    assert coerce_tracer(t) is t
    # Every typed emit on the null tracer is a no-op.
    NULL_TRACER.task_fire(0, "t", 0, 0.0, 0)
    NULL_TRACER.flit_hop(0, 0, 64, 0, 0)
    NULL_TRACER.bank_burst(0, 0, 0, 64, 0, 0)
    assert len(NULL_TRACER) == 0
    assert NULL_TRACER.link_goodput_bytes() == {}
    assert NULL_TRACER.bank_bytes() == {}


def test_typed_emits_match_their_schemas():
    t = Tracer()
    t.task_fire(3, "stage0", 1, 0.5, 0)
    t.task_wait(4, "stage1", 0, "net", 0)
    t.channel_push(5, 0, "a", "b", 128, 0)
    t.flit_hop(6, 2, 64, 0, 9)
    t.bank_burst(7, 5, 0, 512, 0, 1)
    assert len(t) == 5
    for e in t.events:
        assert len(e) == 2 + len(EVENT_FIELDS[e[0]]), e
    d = t.as_dicts()
    assert d[0]["kind"] == "task_fire" and d[0]["task"] == "stage0"
    assert t.count("task_fire") == 1
    assert [e[2] for e in t.iter_kind("flit_hop")] == [2]


def test_metrics_registry_basics():
    reg = MetricsRegistry()
    reg.counter_add("x.y", 2, a="1")
    reg.counter_add("x.y", 3, a="1")
    reg.counter_add("x.y", 5, a="2")
    reg.gauge_set("g", 0.5)
    reg.observe("h", 1.0)
    reg.observe("h", 3.0)
    assert reg.value("x.y", 0, a="1") == 5
    assert reg.total("x.y") == 10
    assert reg.kind("g") == "gauge"
    h = reg.value("h", None)
    assert h["count"] == 2 and h["min"] == 1.0 and h["max"] == 3.0
    j = reg.to_json()
    assert j["x.y"]["type"] == "counter"


# ---------------------------------------------------------------------------
# Exec + net path: transparency, consistency, Chrome export, critpath.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fabric_run():
    cluster = fpga_ring_cluster(2)
    graph = APPS["stencil"].build_graph(2)
    design = tapa_compile(graph, cluster, CompileOptions(
        balance_kind="LUT", balance_tol=0.8, exact_limit=1500,
        fabric=cluster_fabric(cluster),
        passes=("normalize_units", "partition", "congestion_feedback",
                "pipeline_interconnect", "schedule")))
    base = execute(design, bind_programs(graph))
    tracer = Tracer()
    res = execute(design, bind_programs(graph), tracer=tracer)
    return graph, design, base, res, tracer


def test_traced_run_is_bit_identical_and_counter_identical(fabric_run):
    _, _, base, res, tracer = fabric_run
    assert bit_identical(base.outputs, res.outputs)
    assert _counters(base.report) == _counters(res.report)
    assert base.report.trace is None
    assert res.report.trace is tracer


def test_trace_and_registry_reconcile_exactly(fabric_run):
    _, _, _, res, tracer = fabric_run
    assert_trace_report_consistent(tracer, res.report)
    reg = from_report(res.report)
    assert_registry_consistent(reg, res.report)
    # The report's cached registry view is the same reconciliation.
    assert res.report.metrics is res.report.metrics       # cached
    assert_registry_consistent(res.report.metrics, res.report)
    # Trace-derived series carry the trace. prefix and agree per link.
    treg = from_trace(tracer)
    for l in res.report.congestion.links:
        assert treg.value("trace.net.link.goodput_bytes", 0,
                          link=l.index) == l.bytes


def test_chrome_trace_export_is_valid(fabric_run):
    _, _, _, _, tracer = fabric_run
    doc = to_chrome_trace(tracer)
    validate_chrome_trace(doc)
    evs = doc["traceEvents"]
    assert any(e["ph"] == "M" and e["name"] == "process_name" for e in evs)
    assert any(e["ph"] == "M" and e["name"] == "thread_name" for e in evs)
    assert all(isinstance(e["pid"], int) and isinstance(e["tid"], int)
               for e in evs)
    assert doc["otherData"]["format"] == "repro-obs/v1"


def test_critpath_sums_to_makespan_exactly(fabric_run):
    _, design, _, res, tracer = fabric_run
    crit = analyze(tracer, sweeps=res.report.sweeps)
    assert isinstance(crit, CritPath)
    for t in crit.tasks:
        assert sum(t.buckets().values()) == res.report.sweeps, t.task
        assert t.idle >= 0
    row = makespan_row("stencil", design, res.report, crit)
    assert row["measured_sweeps"] == res.report.sweeps
    table = format_table([row])
    assert "stencil" in table and "crit task" in table


def test_empty_trace_analyzes_to_no_tasks():
    crit = analyze(Tracer(), sweeps=5)
    assert crit.tasks == [] and crit.fault_link_sweeps == {}
    with pytest.raises(ValueError):
        crit.critical()


# ---------------------------------------------------------------------------
# Mem path.
# ---------------------------------------------------------------------------

def _readers_graph():
    g = TaskGraph("obs-readers")
    for i in range(2):
        g.add_task(Task(f"r{i}", ResourceProfile({"LUT": 1000.0}),
                        hbm_bytes=128.0, meta={"hbm_bank": 0}))
    g.add_task(Task("sink", ResourceProfile({"LUT": 1000.0})))
    for i in range(2):
        g.add_channel(f"r{i}", "sink", 32, bytes_per_step=4.0)
    return g


def _readers_binding(g, iters=3, elems=32):
    toks = {n: [jnp.full((elems,), float(10 * i + t)) for t in range(iters)]
            for i, n in enumerate(("r0", "r1"))}
    return ProgramBinding(
        graph=g, iterations=iters,
        programs={"r0": lambda i: i["x"], "r1": lambda i: i["x"],
                  "sink": lambda i: i["r0"] + i["r1"]},
        mem_reads={"r0": {"x": toks["r0"]}, "r1": {"x": toks["r1"]}},
        finalize=lambda s: jnp.stack(s["sink"]),
        reference=lambda: jnp.stack([toks["r0"][t] + toks["r1"][t]
                                     for t in range(iters)]),
        atol=0.0)


def test_mem_path_traced_identity_and_byte_agreement():
    cfg = MemConfig(banks_per_device=2, bank_bandwidth_Bps=64e6,
                    credits=2, burst_bytes=64)    # hot bank: genuine waits
    g = _readers_graph()
    design = tapa_compile(g, fpga_ring_cluster(1), CompileOptions(
        balance_kind="LUT", balance_tol=2.0, mem=cfg,
        passes=("normalize_units", "partition",
                "pipeline_interconnect", "schedule")))
    base = execute(design, _readers_binding(g))
    tracer = Tracer()
    res = execute(design, _readers_binding(g), tracer=tracer)
    assert bit_identical(base.outputs, res.outputs)
    assert _counters(base.report) == _counters(res.report)
    assert tracer.count("bank_burst") > 0
    assert tracer.count("mem_issue") > 0
    assert sum(res.report.task_mem_waits.values()) > 0
    assert_trace_report_consistent(tracer, res.report)
    assert_registry_consistent(from_report(res.report), res.report)
    validate_chrome_trace(to_chrome_trace(tracer))
    crit = analyze(tracer, sweeps=res.report.sweeps)
    waits = {t.task: t.memory for t in crit.tasks}
    assert waits["r0"] + waits["r1"] \
        == sum(res.report.task_mem_waits.values())


# ---------------------------------------------------------------------------
# Tenant path.
# ---------------------------------------------------------------------------

def test_tenant_server_traced_identity_and_metrics():
    opts = CompileOptions(balance_kind="LUT", balance_tol=0.8,
                          exact_limit=1500, floorplan_devices=(0,))
    specs = {"a": {"seed": 0}, "b": {"seed": 7}}
    graphs = {n: APPS["stencil"].build_graph(2) for n in specs}
    designs = {n: tapa_compile(graphs[n], fpga_ring_cluster(2), opts)
               for n in specs}

    def tenants():
        return [Tenant("a", designs["a"], device_map=[0, 2],
                       slo=SLO(1e-3, weight=2.0), inputs=specs["a"]),
                Tenant("b", designs["b"], device_map=[0, 1],
                       slo=SLO(1e-3, weight=1.0), inputs=specs["b"])]

    fabric = cluster_fabric(fpga_ring_cluster(4))
    base = TenantServer(fabric, tenants()).run()
    tracer = Tracer()
    server = TenantServer(fabric, tenants(), tracer=tracer)
    out = server.run()
    assert out.sweeps == base.sweeps
    for n in specs:
        assert bit_identical(out.record(n).result.outputs,
                             base.record(n).result.outputs), n
    assert tracer.count("tenant_admit") == 2
    validate_chrome_trace(to_chrome_trace(tracer))
    # Per-flow attribution covers both tenants with distinct flow ids.
    crit = analyze(tracer, sweeps=out.sweeps)
    assert crit.flows() == [0, 1]
    reg = server.metrics()
    assert reg.total("tenant.flow.admissions") == 2
    for rec in out.records:
        rep = rec.result.report
        assert reg.value("tenant.flow.sweeps", 0, tenant=rec.name) \
            == rep.sweeps
        assert reg.value("tenant.flow.net_bytes", 0, tenant=rec.name) \
            == sum(c.net_bytes for c in rep.channels)


def test_tenant_kill_emits_cancel_and_counts_recovery():
    from repro.tenants import DeviceKill
    opts = CompileOptions(balance_kind="LUT", balance_tol=0.8,
                          exact_limit=1500, floorplan_devices=(0,))
    g = APPS["stencil"].build_graph(2)
    design = tapa_compile(g, fpga_ring_cluster(2), opts)
    fabric = cluster_fabric(fpga_ring_cluster(4))
    tracer = Tracer()
    server = TenantServer(
        fabric, [Tenant("a", design, device_map=[0, 2],
                        slo=SLO(1e-3), inputs={"seed": 0})],
        tracer=tracer)
    out = server.run(faults=[DeviceKill(device=2, sweep=2)])
    assert out.record("a").status == "killed"
    assert tracer.count("tenant_cancel") == 1
    assert tracer.count("tenant_admit") == 2          # admit + re-admit
    reg = server.metrics()
    assert reg.total("tenant.flow.kills") == 1
    assert reg.total("tenant.flow.recompiles") == 1


# ---------------------------------------------------------------------------
# Chaos path: ARQ events and fault attribution.
# ---------------------------------------------------------------------------

def test_chaos_drop_cell_attributes_fault_sweeps():
    from repro.chaos.runner import compile_app, run_scenario
    from repro.chaos.scenario import ChaosScenario
    drop = ChaosScenario("drop-mid", drop=0.05, corrupt=0.02,
                         reorder=0.03, seed=5)
    tracer = Tracer()
    cell = run_scenario("stencil", drop, tracer=tracer)
    assert cell["ok"] and cell["bit_identical"]
    assert tracer.count("retransmit") > 0
    validate_chrome_trace(to_chrome_trace(tracer))
    crit = analyze(tracer, sweeps=cell["sweeps"])
    faulted = {e[2] for e in tracer.iter_kind("retransmit")}
    assert any(crit.fault_link_sweeps.get(li, 0) >= 1 for li in faulted)
    assert sum(t.fault for t in crit.tasks) >= 1
    # The traced faulted run still reconciles byte-exactly.
    _, design = compile_app("stencil", 4)
    from repro.chaos.runner import _execute as chaos_execute
    g, design = compile_app("stencil", 4)
    tr2 = Tracer()
    res = chaos_execute(g, design, faults=drop.fault_model(), tracer=tr2)
    assert_trace_report_consistent(tr2, res.report)


# ---------------------------------------------------------------------------
# Deprecation shims.
# ---------------------------------------------------------------------------

def test_deprecated_report_fields_warn_and_alias(fabric_run):
    _, _, _, res, _ = fabric_run
    rep = res.report
    for old, new in (("congestion_waits", "task_congestion_waits"),
                     ("mem_waits", "task_mem_waits"),
                     ("net_retransmit_bytes", "net_retransmit_bytes_total")):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            assert getattr(rep, old) == getattr(rep, new)
        assert any(issubclass(x.category, DeprecationWarning) for x in w), \
            old
        assert any(new in str(x.message) for x in w), old
