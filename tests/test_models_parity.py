"""Train-path vs cached-decode parity for every mixer family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (LayerSpec, MLAConfig, MLSTMConfig, ModelConfig,
                          RGLRUConfig, SLSTMConfig, init_cache, init_params,
                          serve_step)
from repro.models import layers
from repro.models import transformer as T

RNG = jax.random.PRNGKey(0)
B, S, V = 2, 8, 64


def full_logits(params, cfg, toks):
    x = T._embed_inputs(params, cfg, {"tokens": toks})
    pos = jnp.broadcast_to(jnp.arange(toks.shape[1]), toks.shape)
    x, _ = T._run_stack(params, cfg, x, pos)
    x = layers.rmsnorm(params["final_norm"], x,
                       zero_centered=cfg.zero_centered_norm)
    return layers.unembed(T._unembed_table(params, cfg), x)


def decode_logits(params, cfg, toks):
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        cache, lg = serve_step(params, cfg, cache, toks[:, t:t + 1],
                               jnp.int32(t))
        outs.append(lg)
    return jnp.stack(outs, axis=1)


CONFIGS = {
    "gqa": ModelConfig(name="t", d_model=32, vocab=V,
                       pattern=(LayerSpec("gqa", "dense"),),
                       num_superblocks=2, num_heads=4, num_kv_heads=2,
                       head_dim=8, d_ff=64, dtype=jnp.float32,
                       param_dtype=jnp.float32, q_chunk=4),
    "gqa_window": ModelConfig(name="t", d_model=32, vocab=V,
                              pattern=(LayerSpec("gqa", "dense", window=4),),
                              num_superblocks=2, num_heads=4,
                              num_kv_heads=1, head_dim=8, d_ff=64,
                              dtype=jnp.float32, param_dtype=jnp.float32,
                              q_chunk=4),
    "mla": ModelConfig(name="t", d_model=32, vocab=V,
                       pattern=(LayerSpec("mla", "dense"),),
                       num_superblocks=2,
                       mla=MLAConfig(d_model=32, num_heads=4, q_lora_rank=16,
                                     kv_lora_rank=8, qk_nope_dim=8,
                                     qk_rope_dim=4, v_head_dim=8),
                       d_ff=64, dtype=jnp.float32, param_dtype=jnp.float32,
                       q_chunk=4),
    "rglru": ModelConfig(name="t", d_model=32, vocab=V,
                         pattern=(LayerSpec("rglru", "dense"),),
                         num_superblocks=2,
                         rglru=RGLRUConfig(d_model=32, d_rnn=32), d_ff=64,
                         dtype=jnp.float32, param_dtype=jnp.float32),
    "mlstm": ModelConfig(name="t", d_model=32, vocab=V,
                         pattern=(LayerSpec("mlstm", "dense"),),
                         num_superblocks=2,
                         mlstm=MLSTMConfig(d_model=32, num_heads=2, chunk=4),
                         d_ff=64, dtype=jnp.float32,
                         param_dtype=jnp.float32),
    "slstm": ModelConfig(name="t", d_model=32, vocab=V,
                         pattern=(LayerSpec("slstm", "dense"),),
                         num_superblocks=2,
                         slstm=SLSTMConfig(d_model=32, num_heads=2), d_ff=64,
                         dtype=jnp.float32, param_dtype=jnp.float32),
}


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_train_decode_parity(name):
    cfg = CONFIGS[name]
    params = init_params(RNG, cfg)
    toks = jax.random.randint(RNG, (B, S), 0, V)
    full = full_logits(params, cfg, toks)
    dec = decode_logits(params, cfg, toks)
    scale = float(jnp.max(jnp.abs(full))) + 1e-9
    np.testing.assert_allclose(np.asarray(dec) / scale,
                               np.asarray(full) / scale, atol=2e-5)


def test_mlstm_chunk_invariance():
    """Chunked-parallel mLSTM must be chunk-size invariant."""
    from repro.models.recurrent import init_mlstm, mlstm_forward
    x = jax.random.normal(RNG, (1, 8, 16)) * 0.5
    outs = []
    for chunk in (1, 2, 4, 8):
        cfg = MLSTMConfig(d_model=16, num_heads=2, chunk=chunk)
        params = init_mlstm(jax.random.PRNGKey(7), cfg)
        y, _ = mlstm_forward(params, cfg, x)
        outs.append(np.asarray(y))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5)


def test_rglru_associative_scan_matches_loop():
    from repro.models.recurrent import rglru_scan
    a = jax.random.uniform(RNG, (1, 16, 8), minval=0.1, maxval=0.95)
    bx = jax.random.normal(jax.random.PRNGKey(3), (1, 16, 8))
    got = rglru_scan(a, bx)
    h = jnp.zeros((1, 8))
    expect = []
    for t in range(16):
        h = a[:, t] * h + bx[:, t]
        expect.append(h)
    np.testing.assert_allclose(got, jnp.stack(expect, 1), atol=1e-5)
