"""repro.mem — HBM banks, async memory channels, memory feedback.

Covers the bank model's acceptance criteria: all four memory-bound apps
(axpy / dot / gemv / axpydot) are bit-identical through the bank-modeled
path to both the ideal path and the monolithic Pallas reference; the bank
accounting conserves bytes exactly; the measured and projected
MemContentionReports agree on an uncontended config and diverge in the
documented offered-vs-achieved way on a hot bank; and the memory_feedback
pass re-maps (stage 1) or re-partitions with the ``hbm_bank_frac``
capacity (stage 2, ``-membound`` method tag).
"""
import jax.numpy as jnp
import pytest

from repro.apps import APPS
from repro.compiler import CompileOptions, compile as tapa_compile
from repro.core import ResourceProfile, Task, TaskGraph, fpga_ring_cluster
from repro.exec import ProgramBinding, bind_programs, execute
from repro.mem import (AsyncMemChannel, MemConfig, MemorySystem,
                       default_bank_map, measure, project,
                       rebalance_bank_map)


# ---------------------------------------------------------------------------
# Bank mechanics: bursts, budgets, fairness, exact conservation.
# ---------------------------------------------------------------------------

def test_burst_math_and_budget_floor():
    cfg = MemConfig(burst_bytes=512)
    assert cfg.bursts_for(1) == 1
    assert cfg.bursts_for(512) == 1
    assert cfg.bursts_for(513) == 2
    # A bank too slow for even one burst per sweep still gets the floor.
    slow = MemConfig(bank_bandwidth_Bps=1.0, burst_bytes=512)
    assert slow.budget_bursts() == 1


def _drain_memsys(memsys, channels=None, start=0):
    """Step until idle, routing completions back to their channels."""
    sweep = start
    while memsys.active:
        for rid, ci in memsys.step(sweep):
            if channels is not None:
                channels[ci].on_complete(rid, sweep)
        sweep += 1
        assert sweep < 10_000, "memory system failed to make progress"
    return sweep


def test_bank_byte_conservation_is_exact():
    """Odd request sizes: the last burst carries the exact remainder."""
    # 64 B/sweep at the 1 µs base → budget of 1 burst per sweep.
    cfg = MemConfig(banks_per_device=2, bank_bandwidth_Bps=64e6,
                    credits=4, burst_bytes=64)
    ms = MemorySystem(2, cfg)
    sizes = [(0, 0, 0, 1234), (1, 0, 1, 999), (2, 1, 0, 100), (3, 1, 1, 65)]
    for ch, dev, bank, n in sizes:
        ms.submit(ch, dev, bank, n, 0)
    _drain_memsys(ms)
    assert ms.total_served_bytes == ms.total_requested_bytes == \
        sum(n for _, _, _, n in sizes)
    assert sum(c.bytes for c in ms.counters) == ms.total_served_bytes
    assert sum(c.bursts for c in ms.counters) == \
        sum(cfg.bursts_for(n) for _, _, _, n in sizes)
    for bid in range(4):
        assert ms.utilization(bid) <= 1.0


def test_contended_bank_shares_fairly():
    """Two channels on one bank genuinely halve each other's throughput,
    and neither starves (round-robin, one burst per channel per lap)."""
    cfg = MemConfig(banks_per_device=1, bank_bandwidth_Bps=64e6,
                    credits=8, burst_bytes=64)
    solo = MemorySystem(1, cfg)
    solo.submit(0, 0, 0, 8 * 64, 0)
    solo_sweeps = _drain_memsys(solo)

    both = MemorySystem(1, cfg)
    both.submit(0, 0, 0, 8 * 64, 0)
    both.submit(1, 0, 0, 8 * 64, 0)
    done = []
    sweep = 0
    while both.active:
        done.extend(both.step(sweep))
        sweep += 1
    assert sweep >= 2 * solo_sweeps - 1          # bandwidth genuinely shared
    # Both complete within one sweep of each other.
    assert both.counters[0].saturated_sweeps > 0
    assert {ci for _, ci in done} == {0, 1}


# ---------------------------------------------------------------------------
# Async memory channels: credits, reorder window, FIFO responses.
# ---------------------------------------------------------------------------

def _tokens(n, elems=16):
    # elems float32 lanes = 4 × elems bytes per token.
    return [jnp.full((elems,), float(i)) for i in range(n)]


def test_ideal_channel_is_immediate_fifo():
    toks = _tokens(4)
    ch = AsyncMemChannel(0, "t", "x", toks, 4, device=0, bank=0, memsys=None)
    out = []
    for sweep in range(4):
        ch.pump(sweep)
        assert ch.response_ready(sweep)
        out.append(ch.consume(sweep))
    assert ch.total_bursts() == 0                # ideal path: no bank bursts
    assert ch.stats.issued == ch.stats.consumed == 4
    assert ch.stats.delivered_bytes == ch.stats.requested_bytes
    for got, want in zip(out, toks):
        assert bool(jnp.all(got == want))


def test_banked_channel_credits_bound_outstanding():
    cfg = MemConfig(banks_per_device=1, bank_bandwidth_Bps=64e6,
                    credits=2, burst_bytes=64)
    ms = MemorySystem(1, cfg)
    toks = _tokens(6)
    ch = AsyncMemChannel(0, "t", "x", toks, 6, device=0, bank=0, memsys=ms)
    out, sweep = [], 0
    while ch.stats.consumed < ch.count:
        ch.pump(sweep)
        assert ch.outstanding <= cfg.credits
        if ch.response_ready(sweep):
            out.append(ch.consume(sweep))
        for rid, ci in ms.step(sweep):
            ch.on_complete(rid, sweep)
        sweep += 1
        assert sweep < 1000
    # More firings than credits: the pump must have hit request_full.
    assert ch.stats.blocked_issues > 0
    assert ch.stats.max_outstanding == cfg.credits
    assert ch.stats.response_waits > 0           # vis = completion sweep + 1
    assert ch.stats.delivered_bytes == ch.stats.requested_bytes
    # Responses consumed in issue order — bit-exact FIFO.
    for got, want in zip(out, toks):
        assert bool(jnp.all(got == want))


def test_channel_rejects_short_token_list():
    with pytest.raises(ValueError, match="2 tokens < 3 firings"):
        AsyncMemChannel(0, "t", "x", _tokens(2), 3, device=0, bank=0)


# ---------------------------------------------------------------------------
# Bank maps: declared pins, round-robin default, LPT rebalance.
# ---------------------------------------------------------------------------

def _readers_graph(loads, pins=None):
    g = TaskGraph("readers")
    for i, b in enumerate(loads):
        meta = {"hbm_bank": pins[i]} if pins else {}
        g.add_task(Task(f"r{i}", ResourceProfile({"LUT": 1000.0}),
                        hbm_bytes=b, meta=meta))
    g.add_task(Task("sink", ResourceProfile({"LUT": 1000.0})))
    for i in range(len(loads)):
        g.add_channel(f"r{i}", "sink", 32, bytes_per_step=4.0)
    return g


def test_default_bank_map_pins_and_round_robin():
    cfg = MemConfig(banks_per_device=2)
    g = _readers_graph([100, 100, 100], pins=[5, None, None])
    g.tasks["r1"].meta.pop("hbm_bank", None)
    g.tasks["r2"].meta.pop("hbm_bank", None)
    asg = {n: 0 for n in g.tasks}
    m = default_bank_map(g, asg, cfg)
    assert m["r0"] == 5 % 2                      # declared pin, mod banks
    assert m["r1"] == 0 and m["r2"] == 1         # round-robin in graph order
    assert "sink" not in m                       # hbm_bytes == 0: no bank


def test_rebalance_overrides_pins_with_lpt():
    cfg = MemConfig(banks_per_device=2, bank_bandwidth_Bps=1e9)
    g = _readers_graph([800.0, 500.0, 400.0], pins=[0, 0, 0])
    asg = {n: 0 for n in g.tasks}
    pinned = project(g, asg, cfg)                # all on bank 0
    m = rebalance_bank_map(g, asg, cfg)
    spread = project(g, asg, cfg, bank_map=m)
    assert m["r0"] != m["r1"]                    # heaviest two split
    assert spread.max_utilization < pinned.max_utilization
    # LPT: 800 alone, 500+400 together — the best 2-bank makespan.
    assert spread.bank(0, m["r0"]).bytes == 800.0


# ---------------------------------------------------------------------------
# Differential: measured vs projected MemContentionReport.
# ---------------------------------------------------------------------------

def _two_reader_binding(g, iters=3, elems=32):
    # One 128-byte token per firing — exactly each task's hbm_bytes.
    toks = {n: [jnp.full((elems,), float(10 * i + t))
                for t in range(iters)]
            for i, n in enumerate(("r0", "r1"))}
    return ProgramBinding(
        graph=g, iterations=iters,
        programs={"r0": lambda i: i["x"], "r1": lambda i: i["x"],
                  "sink": lambda i: i["r0"] + i["r1"]},
        mem_reads={"r0": {"x": toks["r0"]}, "r1": {"x": toks["r1"]}},
        finalize=lambda s: jnp.stack(s["sink"]),
        reference=lambda: jnp.stack([toks["r0"][t] + toks["r1"][t]
                                     for t in range(iters)]),
        atol=0.0)


def _compile_readers(g, config, feedback=True):
    passes = ["normalize_units", "partition"]
    if feedback:
        passes.append("memory_feedback")
    passes += ["pipeline_interconnect", "schedule"]
    return tapa_compile(g, fpga_ring_cluster(1), CompileOptions(
        balance_kind="LUT", balance_tol=2.0, mem=config,
        passes=tuple(passes)))


def test_uncontended_measured_agrees_with_projection():
    """One reader per bank, service ≥ demand: per-bank measured bytes are
    exactly the projected per-step bytes × iterations, nothing saturates,
    and neither report flags a hotspot."""
    cfg = MemConfig(banks_per_device=2, bank_bandwidth_Bps=256e6,
                    credits=2, burst_bytes=64)   # 256 B/step ≥ 128 B demand
    g = _readers_graph([128.0, 128.0])
    design = _compile_readers(g, cfg)
    binding = _two_reader_binding(g, iters=3)
    rep = execute(design, binding).report
    assert all(rep.agreement().values()), rep.agreement()
    measured, projected = rep.mem_contention, design.mem_contention
    assert measured.kind == "measured" and projected.kind == "projected"
    bank_map = design.bank_map
    for task in ("r0", "r1"):
        b = bank_map[task]
        assert measured.bank(0, b).bytes == \
            projected.bank(0, b).bytes * rep.iterations
        assert measured.bank(0, b).saturated_sweeps == 0
    assert projected.max_utilization == pytest.approx(0.5)
    assert not measured.hotspots(0.75) and not projected.hotspots(0.75)


def test_hot_bank_diverges_offered_vs_achieved():
    """Both readers pinned to one bank, demand 4× service: the projection
    reports *offered* load (> 1, the slowdown factor) while the measured
    utilization is *achieved* throughput (≤ 1) with saturation counted —
    the documented way the two reports are allowed to diverge."""
    cfg = MemConfig(banks_per_device=2, bank_bandwidth_Bps=64e6,
                    credits=2, burst_bytes=64)   # 64 B/step vs 256 B demand
    g = _readers_graph([128.0, 128.0], pins=[0, 0])
    # No memory_feedback: keep the declared pins (the hot configuration).
    design = _compile_readers(g, cfg, feedback=False)
    binding = _two_reader_binding(g, iters=3)
    result = execute(design, binding)
    rep = result.report
    assert bool(jnp.all(result.outputs == binding.reference()))
    assert all(rep.agreement().values()), rep.agreement()
    projected = project(g, {n: 0 for n in g.tasks}, cfg)
    measured = rep.mem_contention
    assert projected.bank(0, 0).utilization == pytest.approx(4.0)
    assert measured.max_utilization <= 1.0 + 1e-12
    assert measured.bank(0, 0).saturated_sweeps > 0
    assert measured.bank(0, 1).bytes == 0        # the other bank idles
    assert sum(rep.task_mem_waits.values()) > 0       # pipeline genuinely stalled
    # Both reports still account the same total traffic per step vs run.
    assert measured.total_bytes == projected.total_bytes * rep.iterations


# ---------------------------------------------------------------------------
# memory_feedback: stage-1 re-map and stage-2 membound repartition.
# ---------------------------------------------------------------------------

def test_memory_feedback_remaps_hot_bank():
    cfg = MemConfig(banks_per_device=2, bank_bandwidth_Bps=1e9)
    per = 0.8 * cfg.bank_bandwidth_Bps * cfg.sweep_time_s
    g = _readers_graph([per, per], pins=[0, 0])
    design = _compile_readers(g, cfg)
    d = design.pass_record("memory_feedback").detail
    assert d["remapped"] and not d["repartitioned"]
    assert d["max_utilization_before"] == pytest.approx(1.6)
    assert d["max_utilization_after"] == pytest.approx(0.8)
    assert design.bank_map["r0"] != design.bank_map["r1"]


def test_membound_repartition_splits_device_aggregate():
    """One bank per device: no re-map can cool a device holding both hot
    readers — the stage-2 repartition must split them, charging bank
    bandwidth as an Eq. 1 capacity and re-tagging the method."""
    cfg = MemConfig(banks_per_device=1, bank_bandwidth_Bps=1e9)
    per = 0.9 * cfg.bank_bandwidth_Bps * cfg.sweep_time_s
    g = TaskGraph("membound")
    for n in ("h0", "h1"):
        g.add_task(Task(n, ResourceProfile({"LUT": 1000.0}), hbm_bytes=per))
    g.add_task(Task("sink", ResourceProfile({"LUT": 1000.0})))
    # Heavy h0—h1 coupling: the plain Eq. 2 objective co-locates them.
    g.add_channel("h0", "h1", 512, bytes_per_step=4096.0)
    g.add_channel("h1", "sink", 32, bytes_per_step=4.0)
    design = tapa_compile(g, fpga_ring_cluster(2), CompileOptions(
        balance_kind="LUT", balance_tol=2.0, mem=cfg,
        passes=("normalize_units", "partition", "memory_feedback")))
    d = design.pass_record("memory_feedback").detail
    assert d["repartitioned"], d
    assert design.partition.stats.method.endswith("-membound")
    a = design.partition.assignment
    assert a["h0"] != a["h1"]                    # the aggregate was split
    assert d["max_utilization_after"] == pytest.approx(0.9)
    assert d["comm_cost_after"] >= d["comm_cost_before"]  # paid in cut bytes


def test_membound_gives_up_when_one_task_outruns_a_device():
    """A single task demanding more than a whole device's banks: no
    partition can fix it — the pass must leave the partition untouched."""
    cfg = MemConfig(banks_per_device=1, bank_bandwidth_Bps=1e9)
    per = 3.0 * cfg.bank_bandwidth_Bps * cfg.sweep_time_s
    g = _readers_graph([per])
    design = tapa_compile(g, fpga_ring_cluster(2), CompileOptions(
        balance_kind="LUT", balance_tol=2.0, mem=cfg,
        passes=("normalize_units", "partition", "memory_feedback")))
    d = design.pass_record("memory_feedback").detail
    assert not d["repartitioned"]
    assert not design.partition.stats.method.endswith("-membound")
    assert d["max_utilization_after"] == pytest.approx(3.0)


def test_compile_inserts_memory_feedback_with_default_passes():
    cfg = MemConfig(banks_per_device=4, bank_bandwidth_Bps=2e9,
                    credits=4, burst_bytes=512)
    g = APPS["axpy"].build_graph(2)
    design = tapa_compile(g, fpga_ring_cluster(2), CompileOptions(
        balance_kind="LUT", balance_tol=0.8, exact_limit=1500,
        floorplan_devices=None, mem=cfg))
    names = [r.name for r in design.pass_records]
    assert "memory_feedback" in names
    assert names.index("memory_feedback") > names.index("partition")
    assert design.bank_map is not None
    assert design.summary()["mem"]["banks_per_device"] == 4


# ---------------------------------------------------------------------------
# The four memory-bound apps: bit-identical through the bank model.
# ---------------------------------------------------------------------------

_MEM_CFG = MemConfig(banks_per_device=4, bank_bandwidth_Bps=2e9,
                     credits=4, burst_bytes=512)
_MEM_OPTS = CompileOptions(
    balance_kind="LUT", balance_tol=0.8, exact_limit=1500,
    floorplan_devices=None, mem=_MEM_CFG,
    passes=("normalize_units", "partition", "memory_feedback",
            "pipeline_interconnect", "schedule"))


@pytest.mark.parametrize("app", ["axpy", "dot", "gemv", "axpydot"])
def test_apps_bit_identical_through_banks(app):
    graph = APPS[app].build_graph(2)
    design = tapa_compile(graph, fpga_ring_cluster(2), _MEM_OPTS)
    binding = bind_programs(graph)
    banked = execute(design, binding)
    ideal = execute(design, bind_programs(graph), mem=None)
    assert bool(jnp.all(banked.outputs == ideal.outputs)), \
        f"{app}: bank model changed numerics"
    assert bool(jnp.all(banked.outputs == binding.reference())), \
        f"{app}: diverged from the Pallas reference (atol is 0.0)"
    rep = banked.report
    agree = rep.agreement()
    assert all(agree.values()), (app, agree)
    assert agree["mem_delivery_match"] and agree["bank_conservation"]
    assert int(rep.mem_bank_bytes) == rep.mem_delivered_bytes > 0
    assert rep.mem_contention.max_utilization <= 1.0 + 1e-12
    # The bank path costs real sweeps; the ideal path never waits on memory.
    assert rep.sweeps > ideal.report.sweeps
    assert sum(rep.task_mem_waits.values()) > 0
    assert not ideal.report.mem_channels or \
        sum(ideal.report.task_mem_waits.values()) == 0


def test_mem_reads_binding_validation():
    g = _readers_graph([64.0, 64.0])
    good = _two_reader_binding(g)
    good.validate()
    with pytest.raises(ValueError, match="unknown task"):
        ProgramBinding(
            graph=g, iterations=1,
            programs=dict(good.programs),
            mem_reads={"r0": {"x": _tokens(1)},
                       "r1": {"x": _tokens(1)},
                       "ghost": {"x": _tokens(1)}}).validate()
    # A memory stream may not shadow a predecessor channel's token name.
    with pytest.raises(ValueError, match="shadow"):
        ProgramBinding(
            graph=g, iterations=1,
            programs=dict(good.programs),
            mem_reads={"r0": {"x": _tokens(1)},
                       "r1": {"x": _tokens(1)},
                       "sink": {"r0": _tokens(1)}}).validate()
