"""Inter-device ILP partitioner (Eq. 1–2): exactness, constraints, pins."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip

from repro.core import (Cluster, DaisyChain, DeviceSpec, ILPError,
                        ResourceProfile, Ring, Task, TaskGraph,
                        fpga_ring_cluster, linear_graph)
# Raw implementation: the repro.core package-level name is a deprecation
# shim (use repro.compiler.compile in new code).
from repro.core.partitioner import partition


def small_cluster(n=2, lut=100.0, thresh=0.7):
    dev = DeviceSpec("d", {"LUT": lut})
    # Raw-die capacities: the hand-counted expectations below (e.g. "cap
    # 180 → max 3 tasks") predate interconnect-IP overhead charging, which
    # has its own coverage in test_net.py.
    return Cluster(dev, Ring(n), utilization_threshold=thresh,
                   charge_interconnect_overhead=False)


def test_chain_partition_is_contiguous():
    g = linear_graph(8, width_bits=512, area={"LUT": 30.0})
    cl = small_cluster(2, lut=200.0)
    p = partition(g, cl)
    # A chain min-cut over 2 devices cuts exactly one edge.
    assert len(p.cut_channels) == 1
    assert p.comm_cost == 512.0


def test_capacity_constraint_respected():
    g = linear_graph(6, width_bits=64, area={"LUT": 50.0})
    cl = small_cluster(2, lut=250.0, thresh=0.8)   # cap 200/device
    p = partition(g, cl)
    for d in range(2):
        used = sum(50.0 for t in p.device_tasks(d))
        assert used <= 200.0 + 1e-6


def test_infeasible_raises():
    g = linear_graph(4, area={"LUT": 100.0})
    cl = small_cluster(2, lut=100.0, thresh=0.5)   # 50 cap <任 one task
    with pytest.raises(ILPError):
        partition(g, cl)


def test_pins_respected():
    g = linear_graph(6, width_bits=64, area={"LUT": 10.0})
    cl = small_cluster(2, lut=500.0)
    p = partition(g, cl, pins={"t0": 1, "t5": 0})
    assert p.assignment["t0"] == 1
    assert p.assignment["t5"] == 0


def test_not_always_min_cut_under_congestion():
    """Paper §4.3: a module moves off-chip when keeping it local would
    violate the threshold, even at higher comm cost."""
    g = TaskGraph("cong")
    for i in range(4):
        g.add_task(Task(f"t{i}", ResourceProfile({"LUT": 60.0})))
    # all tightly connected: min-cut would keep them together
    for i in range(3):
        g.add_channel(f"t{i}", f"t{i+1}", width_bits=1024)
    cl = small_cluster(2, lut=200.0, thresh=0.9)   # cap 180 → max 3 tasks
    p = partition(g, cl)
    sizes = sorted(len(p.device_tasks(d)) for d in range(2))
    assert sizes == [1, 3]          # forced off-chip placement
    assert p.comm_cost > 0


def test_balance_band():
    g = linear_graph(8, width_bits=8, area={"LUT": 10.0})
    cl = small_cluster(2, lut=500.0)
    p = partition(g, cl, balance_kind="LUT", balance_tol=0.1)
    counts = [len(p.device_tasks(d)) for d in range(2)]
    assert counts == [4, 4]


def test_four_device_ring_chain():
    g = linear_graph(16, width_bits=512, area={"LUT": 10.0})
    cl = fpga_ring_cluster(4)
    p = partition(g, cl, balance_kind="LUT", balance_tol=0.3)
    # 3 cuts for a chain over 4 devices, each to an adjacent ring slot.
    assert len(p.cut_channels) == 3
    for c in p.cut_channels:
        d1, d2 = p.assignment[c.src], p.assignment[c.dst]
        assert cl.topology.dist(d1, d2) == 1


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 10), st.integers(2, 3), st.data())
def test_random_graphs_satisfy_eq1(n_tasks, n_dev, data):
    g = TaskGraph("rand")
    for i in range(n_tasks):
        g.add_task(Task(f"t{i}", ResourceProfile(
            {"LUT": data.draw(st.floats(1.0, 40.0))})))
    for i in range(n_tasks - 1):
        g.add_channel(f"t{i}", f"t{i+1}",
                      data.draw(st.integers(8, 1024)))
    # random extra forward edges (DAG)
    for _ in range(data.draw(st.integers(0, 4))):
        a = data.draw(st.integers(0, n_tasks - 2))
        b = data.draw(st.integers(a + 1, n_tasks - 1))
        g.add_channel(f"t{a}", f"t{b}", 64)
    cl = small_cluster(n_dev, lut=200.0, thresh=0.9)
    p = partition(g, cl)
    # every task assigned exactly once, Eq. 1 holds per device
    assert set(p.assignment) == set(g.task_names())
    for d in range(n_dev):
        used = sum(g.tasks[t].area["LUT"] for t in p.device_tasks(d))
        assert used <= 180.0 + 1e-6
    # objective consistency
    recomputed = sum(cl.comm_cost(p.assignment[c.src],
                                  p.assignment[c.dst], c.width_bits)
                     for c in g.channels)
    assert recomputed == pytest.approx(p.comm_cost)
