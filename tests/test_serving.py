"""repro.serving — the batched prefill/decode engine.

The engine's contract: prefill teacher-forces the prompt through the same
``serve_step`` the dry-run lowers; generate continues from the prefill
cache; greedy sampling (temperature 0) is pure argmax and rng-independent;
temperature sampling is deterministic per (rng, salt); cache slots are
fully re-populated per call so an engine can be reused across requests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (LayerSpec, ModelConfig, init_cache, init_params,
                          serve_step)
from repro.serving import ServeConfig, ServingEngine

B, P, V = 2, 6, 64

CFG = ModelConfig(name="t", d_model=32, vocab=V,
                  pattern=(LayerSpec("gqa", "dense"),),
                  num_superblocks=2, num_heads=4, num_kv_heads=2,
                  head_dim=8, d_ff=64, dtype=jnp.float32,
                  param_dtype=jnp.float32, q_chunk=4)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


def _prompts(seed=0):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (B, P), 0, V),
        dtype=np.int32)


def _engine(params, temperature=0.0, slots=B):
    return ServingEngine(params, CFG,
                         ServeConfig(batch_slots=slots, max_len=64,
                                     temperature=temperature))


def test_prefill_matches_manual_serve_step_loop(params):
    eng = _engine(params)
    prompts = _prompts()
    logits, pos = eng.prefill(prompts)
    assert pos == P
    cache = init_cache(CFG, B, 64)
    manual = None
    for t in range(P):
        cache, manual = serve_step(params, CFG, cache,
                                   jnp.asarray(prompts[:, t:t + 1]),
                                   jnp.int32(t))
    assert jnp.array_equal(logits, manual)


def test_generate_shape_and_token_range(params):
    out = _engine(params).generate(_prompts(), max_new=5)
    assert out.shape == (B, 5)
    assert out.dtype == np.int32
    assert np.all((out >= 0) & (out < V))


def test_greedy_is_rng_independent_and_deterministic(params):
    prompts = _prompts()
    a = _engine(params).generate(prompts, max_new=8)
    b = _engine(params).generate(prompts, max_new=8,
                                 rng=jax.random.PRNGKey(123))
    c = _engine(params).generate(prompts, max_new=8,
                                 rng=jax.random.PRNGKey(999))
    # temperature 0 -> argmax; the rng must not matter at all.
    assert np.array_equal(a, b) and np.array_equal(b, c)


def test_greedy_first_token_is_argmax_of_prefill_logits(params):
    eng = _engine(params)
    prompts = _prompts()
    logits, _ = eng.prefill(prompts)
    first = np.asarray(jnp.argmax(logits, axis=-1))
    out = _engine(params).generate(prompts, max_new=1)
    assert np.array_equal(out[:, 0], first)


def test_temperature_sampling_deterministic_per_key(params):
    prompts = _prompts()
    rng = jax.random.PRNGKey(42)
    a = _engine(params, temperature=1.0).generate(prompts, max_new=8,
                                                  rng=rng)
    b = _engine(params, temperature=1.0).generate(prompts, max_new=8,
                                                  rng=rng)
    assert np.array_equal(a, b)
    # No key falls back to greedy even at temperature > 0.
    greedy = _engine(params).generate(prompts, max_new=8)
    nokey = _engine(params, temperature=1.0).generate(prompts, max_new=8)
    assert np.array_equal(nokey, greedy)


def test_hot_temperature_diverges_from_greedy(params):
    prompts = _prompts()
    greedy = _engine(params).generate(prompts, max_new=16)
    hot = _engine(params, temperature=5.0).generate(
        prompts, max_new=16, rng=jax.random.PRNGKey(7))
    assert not np.array_equal(hot, greedy)


def test_slot_reuse_across_requests(params):
    """A second generate on the SAME engine re-populates every cache slot
    from position 0 — reuse is indistinguishable from a fresh engine."""
    eng = _engine(params)
    prompts = _prompts()
    first = eng.generate(prompts, max_new=8)
    again = eng.generate(prompts, max_new=8)
    assert np.array_equal(first, again)
    # New request in the reused slots: same result as a fresh engine's.
    other = _prompts(seed=3)
    reused = eng.generate(other, max_new=8)
    fresh = _engine(params).generate(other, max_new=8)
    assert np.array_equal(reused, fresh)
