"""repro.chaos — lossy links, ARQ, route repair, checkpoint/restore.

The robustness acceptance tests, bottom-up: the fault model's determinism
contract; the transport's reliable-delivery layer (CRC rejection, ARQ
window backpressure, retransmission with capped backoff) keeping delivery
exact under seeded loss; link death triggering route repair (or a named
:class:`PartitionedFabricError` when no route survives); sweep-barrier
snapshots restoring a killed execution bit-identically; and the scenario
matrix tying it together end to end (full matrix is ``-m slow``; a
single-app slice runs in tier 1).
"""
import os
import shutil
import tempfile

import numpy as np
import pytest

from repro.chaos import ChaosScenario, compile_app, default_matrix, \
    run_scenario
from repro.core import DaisyChain, Ring
from repro.core.topology import ETHERNET_100G
from repro.exec import (bind_programs, execute, latest_snapshot_step,
                        load_snapshot, restore_state, resume_execution,
                        save_snapshot, snapshot_steps)
from repro.exec.executor import ExecutionState
from repro.net import (FabricTransport, FaultModel, LinkFaults, NetConfig,
                       PartitionedFabricError, build_fabric)
from repro.net.faults import corrupt_frame, flit_crc, flit_payload
from repro.runtime.fault import FailureInjector
from repro.tenants import bit_identical


def _cfg(budget_flits=2, mtu=64, credits=4):
    bw = ETHERNET_100G.bandwidth_Bps
    return NetConfig(mtu_bytes=mtu, link_credits=credits,
                     sweep_time_s=(budget_flits * mtu) / bw)


def _drain(tr, start=0):
    done, s = [], start
    while tr.active:
        done.extend(tr.step(s))
        s += 1
        assert s < 10_000, "transport failed to make progress"
    return done, s


_PAYLOADS = [(0, 2, 1234), (1, 3, 999), (3, 0, 100), (2, 1, 4001)]


def _run_lossy(faults, payloads=_PAYLOADS, topo=None):
    fab = build_fabric(topo or Ring(4))
    tr = FabricTransport(fab, _cfg(), faults=faults)
    for ch, (s, d, n) in enumerate(payloads):
        tr.submit(ch, s, d, n, 0)
    done, sweeps = _drain(tr)
    return tr, done, sweeps


# ---------------------------------------------------------------------------
# Fault model: validation + determinism contract.
# ---------------------------------------------------------------------------

def test_fault_probabilities_validated():
    with pytest.raises(ValueError):
        LinkFaults(drop=1.5)
    with pytest.raises(ValueError):
        LinkFaults(drop=0.6, corrupt=0.5)     # sum > 1
    with pytest.raises(ValueError):
        FaultModel(backoff_base=0)
    with pytest.raises(ValueError):
        FaultModel(arq_window=0)
    with pytest.raises(ValueError):
        FaultModel(fail_threshold=0)


def test_down_windows_and_lossy_flag():
    lf = LinkFaults(down=((3, 7), (20, None)))
    assert lf.lossy
    assert lf.up(2) and not lf.up(3) and not lf.up(6) and lf.up(7)
    assert not lf.up(100)                     # end=None: never comes back
    assert not LinkFaults().lossy


def test_per_link_rng_streams_are_independent_and_replayable():
    fm = FaultModel(seed=42)
    a1 = fm.rng(0).random(8)
    a2 = fm.rng(0).random(8)
    b = fm.rng(1).random(8)
    np.testing.assert_array_equal(a1, a2)     # same link: same stream
    assert not np.array_equal(a1, b)          # different link: different


def test_crc_catches_every_single_byte_corruption():
    payload = flit_payload(mid=7, flit_index=3, nbytes=4096)
    rng = np.random.default_rng(0)
    for _ in range(64):
        bad = corrupt_frame(payload, rng)
        assert bad != payload
        assert flit_crc(bad) != flit_crc(payload)


# ---------------------------------------------------------------------------
# Reliable delivery: exact books under seeded loss.
# ---------------------------------------------------------------------------

def test_lossy_delivery_is_exact_and_conserving():
    fm = FaultModel(seed=7, default=LinkFaults(drop=0.2, corrupt=0.1,
                                               reorder=0.1))
    tr, done, _ = _run_lossy(fm)
    assert len(done) == len(_PAYLOADS)        # every message delivered
    assert tr.total_delivered_bytes == sum(n for _, _, n in _PAYLOADS)
    # Link bytes count useful crossings only; wasted wire time lives in
    # the separate retransmit ledger — goodput conservation stays exact.
    assert sum(c.bytes for c in tr.counters) \
        == tr.goodput_hop_bytes_total()
    assert sum(c.retransmit_bytes for c in tr.counters) > 0
    assert sum(c.drops + c.crc_errors for c in tr.counters) > 0
    assert tr.arq_books_closed()


def test_same_seed_replays_exactly_different_seed_differs():
    def books(seed):
        fm = FaultModel(seed=seed,
                        default=LinkFaults(drop=0.15, corrupt=0.05))
        tr, done, sweeps = _run_lossy(fm)
        return (sweeps, tuple(done),
                tuple((c.bytes, c.retransmit_bytes, c.drops, c.crc_errors)
                      for c in tr.counters))
    assert books(7) == books(7)
    assert books(7) != books(8)


def test_clean_links_consume_no_rng_and_match_legacy():
    """A FaultModel with zero probabilities must not perturb scheduling:
    same sweeps, same per-link bytes as the faults=None path."""
    base, done0, sweeps0 = _run_lossy(None)
    fm = FaultModel(seed=123)                  # all-zero probabilities
    tr, done1, sweeps1 = _run_lossy(fm)
    assert (sweeps0, done0) == (sweeps1, done1)
    assert [c.bytes for c in base.counters] \
        == [c.bytes for c in tr.counters]
    assert sum(c.retransmit_bytes for c in tr.counters) == 0


def test_arq_window_backpressures_but_delivers():
    # Everything funnels over DaisyChain(2)'s single link pair: one lost
    # flit keeps its seq un-acked through the backoff, so the peers' new
    # transmissions hit the window-of-1 and stall.
    fm = FaultModel(seed=7, default=LinkFaults(drop=0.3), arq_window=1,
                    backoff_base=2, backoff_cap=4)
    payloads = [(0, 1, 640), (0, 1, 640), (0, 1, 640), (1, 0, 640)]
    tr, done, _ = _run_lossy(fm, payloads=payloads, topo=DaisyChain(2))
    assert len(done) == len(payloads)
    assert sum(c.arq_stalls for c in tr.counters) > 0
    assert sum(c.bytes for c in tr.counters) \
        == tr.goodput_hop_bytes_total()
    assert tr.arq_books_closed()


def test_down_window_stalls_then_recovers():
    # Every link dark for sweeps [1, 9): traffic stalls, then completes.
    fm = FaultModel(seed=0, fail_threshold=None,
                    default=LinkFaults(down=((1, 9),)))
    tr, done, sweeps = _run_lossy(fm)
    assert len(done) == len(_PAYLOADS)
    assert sweeps > 9                          # genuinely rode out the dark
    assert sum(c.down_losses for c in tr.counters) > 0
    assert tr.arq_books_closed()


# ---------------------------------------------------------------------------
# Link death -> route repair -> (if cut) PartitionedFabricError.
# ---------------------------------------------------------------------------

def test_permanent_outage_kills_link_and_reroutes():
    fm = FaultModel(seed=0, fail_threshold=3,
                    links={0: LinkFaults(down=((2, None),))})
    tr, done, _ = _run_lossy(fm)
    assert len(done) == len(_PAYLOADS)
    assert 0 in tr.dead_links                  # the cable died...
    assert tr.reroutes >= 1                    # ...and traffic went around
    assert tr.total_delivered_bytes == sum(n for _, _, n in _PAYLOADS)
    # Repair-aware conservation: recalled crossings were reclassified
    # goodput -> retransmit, so the identity holds mid-repair too.
    assert sum(c.bytes for c in tr.counters) \
        == tr.goodput_hop_bytes_total()
    assert tr.arq_books_closed()


def test_partition_raises_named_error():
    # DaisyChain(4): killing the middle cable cuts {0,1} from {2,3}.
    fab = build_fabric(DaisyChain(4))
    middle = [li for li, l in enumerate(fab.links)
              if {l.src, l.dst} == {1, 2}]
    fm = FaultModel(seed=0, fail_threshold=2,
                    links={li: LinkFaults(down=((0, None),))
                           for li in middle})
    tr = FabricTransport(fab, _cfg(), faults=fm)
    tr.submit(0, 0, 3, 500, 0)
    with pytest.raises(PartitionedFabricError) as ei:
        _drain(tr)
    assert ei.value.src in (0, 1) and ei.value.dst in (2, 3)
    assert set(ei.value.dead_links) == set(middle)
    assert tr.partition_error is ei.value


# ---------------------------------------------------------------------------
# End-to-end: compiled app through a lossy fabric (bit-identity).
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def stencil4():
    graph, design = compile_app("stencil", 4)
    baseline = execute(design, bind_programs(graph))
    return graph, design, baseline


def test_lossy_run_is_bit_identical_with_agreement(stencil4):
    graph, design, baseline = stencil4
    fm = FaultModel(seed=11, default=LinkFaults(drop=0.05, corrupt=0.02,
                                                reorder=0.03))
    result = execute(design, bind_programs(graph), faults=fm)
    assert bit_identical(result.outputs, baseline.outputs)
    assert all(result.report.agreement().values())
    assert result.report.sweeps >= baseline.report.sweeps
    assert result.report.net_goodput_hop_bytes is not None


def test_faults_none_report_has_no_fault_fields(stencil4):
    _, _, baseline = stencil4
    assert baseline.report.net_goodput_hop_bytes is None
    assert baseline.report.net_retransmit_bytes_total == 0


# ---------------------------------------------------------------------------
# Sweep-barrier snapshots: atomic publish, kill, restore.
# ---------------------------------------------------------------------------

def test_snapshot_kill_restore_is_bit_identical(stencil4):
    graph, design, baseline = stencil4
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FailureInjector.Injected):
            execute(design, bind_programs(graph),
                    injector=FailureInjector(fail_at_steps=[5]),
                    checkpoint_dir=d, checkpoint_every=3)
        steps = snapshot_steps(d)
        assert steps and steps[-1] < 5         # barriers predate the kill
        resumed = resume_execution(design, d, binding=bind_programs(graph))
        assert bit_identical(resumed.outputs, baseline.outputs)
        assert all(resumed.report.agreement().values())
        # The kill cost sweeps-since-barrier, not a re-run.
        assert resumed.report.sweeps - baseline.report.sweeps <= 3 + 16


def test_snapshot_publish_is_atomic_and_tmp_ignored(stencil4):
    graph, design, _ = stencil4
    state = ExecutionState(design, bind_programs(graph))
    with tempfile.TemporaryDirectory() as d:
        path = save_snapshot(state, 0, d)
        assert os.path.isdir(path) and not path.endswith(".tmp")
        # Crashed-writer leftovers are never listed as restorable.
        os.makedirs(os.path.join(d, "step_9.tmp"))
        assert snapshot_steps(d) == [0]
        assert latest_snapshot_step(d) == 0
        # Re-saving the same barrier keeps the published dir (deterministic
        # content): no error, same path.
        assert save_snapshot(state, 0, d) == path


def test_restore_rejects_mismatched_design(stencil4):
    graph, design, _ = stencil4
    state = ExecutionState(design, bind_programs(graph))
    with tempfile.TemporaryDirectory() as d:
        save_snapshot(state, 2, d)
        payload = load_snapshot(d, 2)
        bad = dict(payload, graph="not-this-graph")
        fresh = ExecutionState(design, bind_programs(graph))
        with pytest.raises(ValueError):
            restore_state(fresh, bad)
        with pytest.raises(FileNotFoundError):
            resume_execution(design, os.path.join(d, "nope"))


def test_checkpoint_every_requires_directory(stencil4):
    graph, design, _ = stencil4
    with pytest.raises(ValueError):
        execute(design, bind_programs(graph), checkpoint_every=4)


# ---------------------------------------------------------------------------
# The scenario matrix.
# ---------------------------------------------------------------------------

def test_scenario_fault_model_mapping():
    assert ChaosScenario("clean").fault_model() is None
    sc = ChaosScenario("x", drop=0.1, down={5: ((0, 6),)},
                       fail_threshold=4, seed=9)
    fm = sc.fault_model()
    assert fm.seed == 9 and fm.fail_threshold == 4
    assert fm.for_link(5).down == ((0, 6),)
    assert fm.for_link(0).drop == 0.1 and fm.for_link(0).down == ()


def test_default_matrix_shape():
    names = [sc.name for sc in default_matrix()]
    assert len([sc for sc in default_matrix()
                if sc.lossy and not sc.down]) >= 3     # 3 drop tiers
    assert len([sc for sc in default_matrix() if sc.down]) >= 2
    assert any(sc.kill_sweep is not None for sc in default_matrix())
    assert len(set(names)) == len(names)


def test_matrix_cell_stencil_drop(stencil4):
    _, _, baseline = stencil4
    cell = run_scenario(
        "stencil",
        ChaosScenario("drop-mid", drop=0.05, corrupt=0.02, reorder=0.03,
                      seed=5),
        baseline=baseline)
    assert cell["ok"] and cell["bit_identical"]
    assert cell["retransmit_bytes"] > 0
    assert cell["overhead_sweeps"] >= 0


def test_matrix_cell_stencil_kill_restore(stencil4):
    _, _, baseline = stencil4
    cell = run_scenario(
        "stencil",
        ChaosScenario("kill-restore", kill_sweep=6, barrier=4, seed=17),
        baseline=baseline)
    assert cell["ok"]
    assert cell["restore_extra_sweeps"] <= 4 + 16


@pytest.mark.slow
def test_full_matrix_all_apps():
    from repro.chaos import run_matrix
    matrix = run_matrix()
    assert matrix["ok"]
    assert len(matrix["cells"]) == 4 * len(default_matrix())
