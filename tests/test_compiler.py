"""repro.compiler: golden equivalence vs the legacy hand-wired chain,
bit-exact unit-normalization round trips, pipeline composition, the
num_devices fix, and the deprecation shims."""
import json
import math

import numpy as np
import pytest

import repro.core as core
from repro.apps import knn
from repro.compiler import (CompileError, CompileOptions, CompilerPipeline,
                            DEFAULT_PASSES)
from repro.compiler import compile as tapa_compile
from repro.core import (ALVEO_U55C, ResourceProfile, Task, TaskGraph,
                        fpga_ring_cluster, linear_graph, tpu_pod_cluster)
from repro.core.costmodel import simulate
from repro.core.floorplan import floorplan_device as raw_floorplan_device
from repro.core.partitioner import partition as raw_partition
from repro.core.pipelining import pipeline_interconnect as raw_pipeline


def test_golden_knn_ring_matches_legacy_chain():
    """End-to-end: KNN on a 4-FPGA ring through compile() must match the
    legacy hand-wired chain pass-for-pass (assignment, floorplan, FIFO
    depths, simulated makespan)."""
    cl = fpga_ring_cluster(4)
    # exact_limit below the problem size keeps both sides on the fast
    # recursive-bisect path so each MILP solves well inside its budget.
    g1 = knn.build_graph(4)
    p1 = raw_partition(g1, cl, balance_kind="LUT", balance_tol=0.8,
                       exact_limit=100, time_limit=30.0)
    d_small = min((d for d in range(4) if p1.device_tasks(d)),
                  key=lambda d: len(p1.device_tasks(d)))
    fp1 = raw_floorplan_device(
        g1, p1.device_tasks(d_small), ALVEO_U55C.resources,
        hbm_tasks=[t for t in p1.device_tasks(d_small)
                   if t.startswith("dist")])
    rep1 = raw_pipeline(g1, p1, {d_small: fp1}, cl)
    res1 = simulate(g1, p1, cl, {d: 220e6 for d in range(4)})

    g2 = knn.build_graph(4)
    design = tapa_compile(g2, cl, CompileOptions(
        balance_kind="LUT", balance_tol=0.8,
        exact_limit=100, partition_time_limit=30.0,
        floorplan_devices=(d_small,),
        hbm_tasks=tuple(t for t in g2.tasks if t.startswith("dist")),
        freq_hz=220e6))

    assert [r.name for r in design.pass_records] == list(DEFAULT_PASSES)
    p2 = design.partition
    assert p2.assignment == p1.assignment
    assert p2.comm_cost == p1.comm_cost
    np.testing.assert_array_equal(p2.usage, p1.usage)
    fp2 = design.floorplans[d_small]
    assert fp2.slot_of == fp1.slot_of
    assert fp2.wirelength == fp1.wirelength
    assert design.pipeline_report.added_latency == rep1.added_latency
    assert design.pipeline_report.depth == rep1.depth
    # Depths were written back onto the caller's graph, as before.
    assert [c.depth for c in g2.channels] == [c.depth for c in g1.channels]
    assert design.schedule.makespan == res1.makespan
    # Artifact digest is JSON-clean and carries every stage.
    digest = json.loads(design.to_json())
    assert {"partition", "floorplans", "pipeline", "schedule",
            "passes"} <= set(digest)


def _tpu_like_graph(n=8):
    g = TaskGraph("lm-chain")
    for i in range(n):
        g.add_task(Task(f"l{i}", ResourceProfile(
            {"hbm_bytes": (3.1 + i) * 1e9,
             "flops": (1.7 + 0.3 * i) * 1e15})))
    for i in range(n - 1):
        g.add_channel(f"l{i}", f"l{i + 1}", 512, bytes_per_step=2e6)
    return g


def test_unit_normalization_round_trips_exactly():
    """The normalize_units pass must (a) never touch the caller's graph or
    cluster, (b) use power-of-two scales, (c) report usage in original
    units bit-exactly — replacing the in-place rescaling that used to live
    in launch/plan.py."""
    g = _tpu_like_graph()
    orig_areas = {n: dict(t.area.amounts) for n, t in g.tasks.items()}
    cl = tpu_pod_cluster(2)
    orig_resources = dict(cl.device.resources)
    design = tapa_compile(g, cl, CompileOptions(
        passes=("normalize_units", "partition", "pipeline_interconnect"),
        balance_kind="flops", balance_tol=0.9,
        capacity_override={"hbm_bytes": 16 * 1024 ** 3 * 256},
        relax_capacity_kinds=("flops",)))

    # (a) no in-place mutation of areas or the (module-global) DeviceSpec.
    assert {n: dict(t.area.amounts) for n, t in g.tasks.items()} == orig_areas
    assert cl.device.resources == orig_resources
    # (b) nontrivial power-of-two scales for both out-of-range kinds.
    assert design.unit_scale["hbm_bytes"] > 1.0
    assert design.unit_scale["flops"] > 1.0
    for s in design.unit_scale.values():
        assert math.frexp(s)[0] == 0.5          # exact power of two
    # Scaled areas round-trip bit-for-bit.
    for t in g.tasks.values():
        for k, v in t.area.amounts.items():
            s = design.unit_scale[k]
            assert (v / s) * s == v
    # (c) usage comes back in original units, exactly.
    p = design.partition
    assert p.num_devices() == 2
    for d in range(2):
        for ki, k in enumerate(p.kinds):
            expect = 0.0
            for name, dd in p.assignment.items():
                if dd == d:
                    expect += g.tasks[name].area[k]
            assert p.usage[d, ki] == expect
    # Subset pipeline: later stages simply absent from the artifact.
    assert design.floorplans == {}
    assert design.schedule is None
    assert design.pipeline_report is not None


def test_fpga_scale_units_pass_through_unscaled():
    g = linear_graph(4, width_bits=64, area={"LUT": 30000.0, "DSP": 64.0})
    design = tapa_compile(g, fpga_ring_cluster(2), CompileOptions(
        passes=("normalize_units", "partition")))
    assert all(s == 1.0 for s in design.unit_scale.values())


def test_partition_num_devices_counts_empty_devices():
    """num_devices() must report the cluster size even when high-indexed
    devices received no tasks (the old max(assignment)+1 undercounted)."""
    g = linear_graph(3, width_bits=64, area={"LUT": 10.0})
    p = raw_partition(g, fpga_ring_cluster(4))
    # Min-cut with ample capacity co-locates everything…
    assert len(set(p.assignment.values())) < 4
    # …but the partition still describes a 4-device cluster.
    assert p.num_devices() == 4
    assert p.usage.shape[0] == 4


def test_unknown_pass_rejected():
    with pytest.raises(CompileError, match="unknown pass"):
        CompilerPipeline(("partition", "no_such_pass"))


def test_later_passes_require_partition():
    g = linear_graph(2, area={"LUT": 10.0})
    for lone in ("floorplan", "pipeline_interconnect", "schedule"):
        with pytest.raises(CompileError, match="requires a partition"):
            tapa_compile(g, fpga_ring_cluster(2),
                         CompileOptions(passes=(lone,)))


def test_empty_passes_runs_no_passes():
    g = linear_graph(2, area={"LUT": 10.0})
    design = tapa_compile(g, fpga_ring_cluster(2),
                          CompileOptions(passes=()))
    assert design.pass_records == ()
    assert design.partition is None and design.schedule is None


def test_pipeline_rejects_conflicting_options_passes():
    g = linear_graph(2, area={"LUT": 10.0})
    with pytest.raises(CompileError, match="conflicts"):
        CompilerPipeline(("partition",)).run(
            g, fpga_ring_cluster(2), CompileOptions(passes=("schedule",)))


def test_explicit_empty_floorplan_device_rejected():
    g = linear_graph(3, width_bits=64, area={"LUT": 10.0})
    # Min-cut co-locates everything on one device, so some explicitly
    # requested device is guaranteed empty (and 7 is out of range).
    with pytest.raises(CompileError, match="received no tasks"):
        tapa_compile(g, fpga_ring_cluster(4), CompileOptions(
            passes=("normalize_units", "partition", "floorplan"),
            floorplan_devices=(0, 1, 2, 3, 7)))


def test_legacy_entry_points_emit_deprecation_warnings():
    g = linear_graph(2, width_bits=64, area={"LUT": 10.0})
    cl = fpga_ring_cluster(2)
    with pytest.warns(DeprecationWarning, match="repro.compiler.compile"):
        p = core.partition(g, cl)
    with pytest.warns(DeprecationWarning, match="repro.compiler.compile"):
        core.floorplan_device(g, g.task_names(), ALVEO_U55C.resources)
    with pytest.warns(DeprecationWarning, match="repro.compiler.compile"):
        core.pipeline_interconnect(g, p, cluster=cl)
