"""repro.net — fabric routing, flit transport, congestion feedback.

Covers the §4.3 acceptance criteria: fabric execution is bit-identical to
the ideal path with exact per-link byte conservation; the λ cross-check
(PCIe Gen3x16 route costs 12.5× the Ethernet route on identical traffic);
a hot-spotted bus triggers the congestion_feedback repartition and
measurably reduces max link utilization; and the interconnect IP's
resource overhead (paper §4.4 Table 10) is charged to device capacity.
"""
import jax.numpy as jnp
import pytest

from repro.apps import APPS
from repro.compiler import CompileOptions, compile as tapa_compile
from repro.core import (ALVEO_U55C, Bus, Cluster, DaisyChain, Hypercube,
                        Mesh2D, ResourceProfile, Ring, Star, Task,
                        TaskGraph, fpga_ring_cluster)
from repro.core.ilp import ILPError
from repro.core.topology import ETHERNET_100G, PCIE_GEN3X16, Protocol, lam
from repro.exec import ProgramBinding, SOURCE_KEY, bind_programs, execute
from repro.net import (FabricTransport, NetConfig, build_fabric,
                       calibrated_pair_cost, cluster_fabric,
                       lambda_crosscheck, project)

ALL_TOPOS = [DaisyChain(5), Ring(6), Bus(4), Star(5), Mesh2D(3, 4),
             Mesh2D(3, 4, torus=True), Hypercube(3)]


# ---------------------------------------------------------------------------
# Fabric: link derivation + deterministic routing.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("topo", ALL_TOPOS, ids=lambda t: t.kind)
def test_route_hops_match_dist(topo):
    """Fabric routes realize the Eq. 3 metric exactly, per topology kind."""
    fab = build_fabric(topo)
    n = topo.num_devices
    for i in range(n):
        for j in range(n):
            assert fab.hops(i, j) == topo.dist(i, j), (topo.kind, i, j)


@pytest.mark.parametrize("topo", ALL_TOPOS, ids=lambda t: t.kind)
def test_routes_deterministic_and_link_valid(topo):
    fab = build_fabric(topo)
    n = topo.num_devices
    for i in range(n):
        for j in range(n):
            r1, r2 = fab.route(i, j), fab.route(i, j)
            assert r1 == r2
            # Consecutive links chain src->...->dst.
            if r1 and not fab.links[r1[0]].shared:
                assert fab.links[r1[0]].src == i
                assert fab.links[r1[-1]].dst == j
                for a, b in zip(r1, r1[1:]):
                    assert fab.links[a].dst == fab.links[b].src


def test_bus_is_one_shared_link():
    fab = build_fabric(Bus(5))
    assert len(fab.links) == 1 and fab.links[0].shared
    for i in range(5):
        for j in range(5):
            if i != j:
                assert fab.route(i, j) == (0,)


def test_star_routes_transit_the_hub():
    fab = build_fabric(Star(5))
    route = fab.route(2, 4)
    assert len(route) == 2
    assert fab.links[route[0]].dst == 0     # spoke -> hub
    assert fab.links[route[1]].src == 0     # hub -> spoke


def test_route_cost_matches_cluster_comm_cost():
    """Per-link Eq. 2 == the partitioner's width × dist × λ on a uniform
    fabric — the invariant the congestion calibration relies on."""
    cluster = fpga_ring_cluster(6)
    fab = cluster_fabric(cluster)
    for i in range(6):
        for j in range(6):
            assert fab.route_cost(i, j, 512.0) == pytest.approx(
                cluster.comm_cost(i, j, 512.0), abs=1e-12)


def test_lambda_crosscheck_pcie_is_12_5x():
    """§4.3: identical traffic over identical routes, PCIe vs Ethernet."""
    topo = Ring(4)
    eth = build_fabric(topo, ETHERNET_100G)
    pcie = build_fabric(topo, PCIE_GEN3X16)
    traffic = [(i, j, 512.0) for i in range(4) for j in range(4) if i != j]
    res = lambda_crosscheck(eth, pcie, traffic)
    assert res["ratio"] == pytest.approx(12.5, abs=1e-9)


# ---------------------------------------------------------------------------
# Transport: contention, fairness, backpressure, conservation.
# ---------------------------------------------------------------------------

def _drain(tr, start=0):
    done, s = [], start
    while tr.active:
        done.extend(tr.step(s))
        s += 1
        assert s < 10_000, "transport failed to make progress"
    return done, s


def _cfg(budget_flits=2, mtu=64, credits=4):
    # sweep_time sized so an Ethernet link moves `budget_flits` per sweep.
    bw = ETHERNET_100G.bandwidth_Bps
    return NetConfig(mtu_bytes=mtu, link_credits=credits,
                     sweep_time_s=(budget_flits * mtu) / bw)


def test_contended_link_halves_throughput():
    fab = build_fabric(DaisyChain(2))
    solo = FabricTransport(fab, _cfg())
    solo.submit(0, 0, 1, 8 * 64, 0)          # 8 flits
    _, solo_sweeps = _drain(solo)

    both = FabricTransport(fab, _cfg())
    both.submit(0, 0, 1, 8 * 64, 0)
    both.submit(1, 0, 1, 8 * 64, 0)
    done, both_sweeps = _drain(both)
    assert both_sweeps >= 2 * solo_sweeps - 1     # bandwidth genuinely shared
    # Fair round-robin: neither message starves the other.
    assert abs(done[0][0] - done[1][0]) == 1      # both complete, adjacent


def test_credit_backpressure_records_stalls():
    """A two-hop flow whose second link is contended backs up into the
    first link's credit window — the stall is counted upstream."""
    fab = build_fabric(DaisyChain(3))
    tr = FabricTransport(fab, _cfg(budget_flits=2, credits=2))
    l01 = fab.route(0, 2)[0]
    tr.submit(0, 0, 2, 32 * 64, 0)           # 32 flits over 0->1->2
    tr.submit(1, 1, 2, 32 * 64, 0)           # contends for 1->2 only
    _drain(tr)
    assert tr.counters[l01].stalled_flits > 0
    assert tr.counters[l01].peak_queue <= tr.config.link_credits


def test_transport_byte_conservation_is_exact():
    fab = build_fabric(Ring(4))
    tr = FabricTransport(fab, _cfg(mtu=100))
    payloads = [(0, 2, 1234), (1, 3, 999), (3, 0, 100), (2, 1, 4001)]
    expect_link_bytes = sum(n * fab.hops(s, d) for s, d, n in payloads)
    for ch, (s, d, n) in enumerate(payloads):
        tr.submit(ch, s, d, n, 0)
    _drain(tr)
    assert tr.total_delivered_bytes == sum(n for _, _, n in payloads)
    assert sum(c.bytes for c in tr.counters) == expect_link_bytes
    assert sum(c.flits for c in tr.counters) == sum(
        tr.config.flits_for(n) * fab.hops(s, d) for s, d, n in payloads)


# ---------------------------------------------------------------------------
# Latency-aware calibration: Protocol.latency_s -> per-hop sweep delay.
# ---------------------------------------------------------------------------

def _delivery_sweep(fab, cfg, src, dst):
    tr = FabricTransport(fab, cfg)
    tr.submit(0, src, dst, cfg.mtu_bytes, 0)     # exactly one flit
    s = 0
    while tr.active:
        if tr.step(s):
            return s
        s += 1
        assert s < 10_000, "transport failed to make progress"
    raise AssertionError("message vanished without delivering")


def test_hop_latency_two_hops_cost_exactly_twice_the_delay():
    """The satellite's identity: with ``hop_latency`` on, an n-hop route
    delivers exactly ``n × ceil(latency_s / sweep_time)`` sweeps later
    than its zero-latency delivery — measured for n=1 and n=2."""
    import dataclasses as dc
    import math
    fab = build_fabric(DaisyChain(3))            # routes 0->1 and 0->1->2
    base = _cfg()
    lat = dc.replace(base, hop_latency=True)
    delay = math.ceil(ETHERNET_100G.latency_s / base.sweep_time_s)
    assert delay > 1                             # the knob actually bites
    assert lat.hop_delay(ETHERNET_100G.latency_s) == 1 + delay
    one_base = _delivery_sweep(fab, base, 0, 1)
    two_base = _delivery_sweep(fab, base, 0, 2)
    one_lat = _delivery_sweep(fab, lat, 0, 1)
    two_lat = _delivery_sweep(fab, lat, 0, 2)
    assert one_lat - one_base == delay
    assert two_lat - two_base == 2 * delay


def test_hop_latency_off_is_the_legacy_time_base():
    fab = build_fabric(DaisyChain(3))
    cfg = _cfg()
    assert cfg.hop_delay(ETHERNET_100G.latency_s) == 1
    assert _delivery_sweep(fab, cfg, 0, 2) == _delivery_sweep(fab, cfg, 0, 2)


# ---------------------------------------------------------------------------
# Weighted flows: DRR shares, per-flow attribution, cancellation.
# ---------------------------------------------------------------------------

def test_weighted_flows_split_a_backlogged_link_by_weight():
    """Two flows saturate one link with equal payloads at weights 2:1 —
    the heavy flow finishes first, and at its finish the light flow has
    crossed about half as many flits (its 1:2 DRR share)."""
    fab = build_fabric(DaisyChain(2))
    tr = FabricTransport(fab, _cfg(), flow_weights={0: 2.0, 1: 1.0})
    flits = 30
    tr.submit(0, 0, 1, flits * 64, 0, flow=0)
    tr.submit(1, 0, 1, flits * 64, 0, flow=1)
    link = fab.route(0, 1)[0]
    s, heavy_done = 0, None
    while tr.active:
        for _, ch in tr.step(s):
            if ch == 0 and heavy_done is None:
                heavy_done = s
                light_flits = tr.counters[link].flow_flits.get(1, 0)
        s += 1
        assert s < 10_000
    assert heavy_done is not None and tr.flow_active(0) is False
    # Light flow's share while both were backlogged: 1/3 of the crossed
    # flits (±1 flit of DRR quantization) against the heavy flow's 30.
    assert abs(light_flits - flits / 2) <= 2, light_flits
    # Everything still drains and the per-flow buckets stay exact.
    c = tr.counters[link]
    assert c.flow_flits[0] == c.flow_flits[1] == flits
    assert sum(c.flow_bytes.values()) == c.bytes


def test_flow_byte_attribution_sums_exactly_per_link():
    fab = build_fabric(Ring(4))
    tr = FabricTransport(fab, _cfg(mtu=100),
                         flow_weights={0: 1.0, 1: 3.0})
    payloads = [(0, 2, 1234, 0), (1, 3, 999, 1), (3, 0, 100, 0),
                (2, 1, 4001, 1)]
    for ch, (s, d, n, f) in enumerate(payloads):
        tr.submit(ch, s, d, n, 0, flow=f)
    _drain(tr)
    for c in tr.counters:
        assert sum(c.flow_bytes.values()) == c.bytes
        assert sum(c.flow_flits.values()) == c.flits
    per_flow = {f: sum(n * fab.hops(s, d)
                       for s, d, n, g in payloads if g == f)
                for f in (0, 1)}
    assert tr.flow_link_bytes(0) == per_flow[0]
    assert tr.flow_link_bytes(1) == per_flow[1]


def test_cancel_flow_drains_without_touching_peers():
    """Cancelling one flow mid-drain releases its credits and leaves the
    surviving flow's stream and accounting untouched — the substrate half
    of the tenant fault story."""
    fab = build_fabric(DaisyChain(3))
    mk = lambda: FabricTransport(fab, _cfg(),  # noqa: E731
                                 flow_weights={0: 1.0, 1: 1.0})
    solo = mk()
    solo.submit(1, 0, 2, 20 * 64, 0, flow=1)
    _, solo_sweeps = _drain(solo)
    solo_bytes = solo.flow_link_bytes(1)

    tr = mk()
    tr.submit(0, 0, 2, 20 * 64, 0, flow=0)
    tr.submit(1, 0, 2, 20 * 64, 0, flow=1)
    for s in range(3):
        tr.step(s)
    cancelled = tr.cancel_flow(0)
    assert cancelled and not tr.flow_active(0)
    assert tr.flow_active(1)
    done, end = _drain(tr, start=3)
    assert [ch for _, ch in done] == [1]         # only the survivor lands
    # Post-cancel the survivor owns the full pipe: it finishes within the
    # solo bound (plus the shared prefix), and conservation stays exact.
    assert end <= solo_sweeps + 3
    assert tr.flow_link_bytes(1) == solo_bytes
    for c in tr.counters:
        assert sum(c.flow_bytes.values()) == c.bytes
    # Cancelled bytes that already crossed stay attributed to flow 0.
    assert tr.flow_link_bytes(0) > 0


def test_flow_weights_validation():
    fab = build_fabric(DaisyChain(2))
    with pytest.raises(ValueError):
        FabricTransport(fab, _cfg(), flow_weights={0: 0.0})
    tr = FabricTransport(fab, _cfg(), flow_weights={0: 1.0})
    with pytest.raises(ValueError):
        tr.submit(0, 0, 1, 64, 0, flow=7)        # undeclared flow


# ---------------------------------------------------------------------------
# Executed designs: acceptance — bit-identical numerics + conservation.
# ---------------------------------------------------------------------------

_NET_OPTS = CompileOptions(
    balance_kind="LUT", balance_tol=0.8, exact_limit=1500,
    partition_time_limit=20.0,
    passes=("normalize_units", "partition", "congestion_feedback",
            "pipeline_interconnect", "schedule"))


@pytest.mark.slow
@pytest.mark.parametrize("app", ["stencil", "pagerank", "knn", "cnn"])
def test_ring4_apps_bit_identical_through_fabric(app):
    cluster = fpga_ring_cluster(4)
    graph = APPS[app].build_graph(4)
    design = tapa_compile(graph, cluster, _NET_OPTS.replace(
        fabric=cluster_fabric(cluster)))
    binding = bind_programs(graph)
    via_net = execute(design, binding)
    ideal = execute(design, bind_programs(graph), fabric=None)
    got_n, got_i = via_net.outputs, ideal.outputs
    if app == "knn":
        got_n, got_i = got_n[0], got_i[0]
    assert bool(jnp.all(got_n == got_i)), f"{app}: fabric changed numerics"
    agree = via_net.report.agreement()
    assert all(agree.values()), (app, agree)
    # Per-link byte totals sum exactly to the hop-weighted cut-set traffic.
    rep = via_net.report
    assert rep.net_link_bytes == rep.net_hop_weighted_bytes
    assert rep.net_submitted_bytes == sum(
        c.net_delivered_bytes for c in rep.channels)


def test_report_net_section_and_route_cost():
    cluster = fpga_ring_cluster(4)
    graph = APPS["stencil"].build_graph(4)
    design = tapa_compile(graph, cluster, _NET_OPTS.replace(
        fabric=cluster_fabric(cluster)))
    rep = execute(design, bind_programs(graph)).report
    assert rep.used_fabric
    # Uniform fabric: per-link Eq. 2 over the cut == the partition objective.
    assert rep.measured_route_comm_cost == pytest.approx(
        design.partition.comm_cost, rel=1e-9)
    summ = rep.summary()["net"]
    assert summ["link_bytes"] == summ["hop_weighted_bytes"]
    assert any(l["bytes"] > 0 for l in summ["links"])
    # The artifact carries the fabric + the projected congestion report.
    assert design.fabric is not None
    assert design.summary()["net"]["topology"] == "ring"


# ---------------------------------------------------------------------------
# Congestion feedback: hot-spotted bus repartition.
# ---------------------------------------------------------------------------

def _hot_bus_graph():
    """Two tightly-coupled pairs; a compute-balance band splits the pairs
    across devices, putting two torrents on the one shared bus link."""
    g = TaskGraph("hotbus")
    lut = {"a": 350e3, "b": 350e3, "c": 150e3, "d": 150e3}
    for n, l in lut.items():
        g.add_task(Task(n, ResourceProfile({"LUT": l})))
    g.add_channel("a", "b", 4096, bytes_per_step=65536.0)   # heavy
    g.add_channel("b", "c", 64, bytes_per_step=8.0)         # light
    g.add_channel("c", "d", 4096, bytes_per_step=65536.0)   # heavy
    return g


def test_hot_bus_triggers_congested_repartition():
    cluster = Cluster(ALVEO_U55C, Bus(2))
    fabric = cluster_fabric(cluster)
    opts = CompileOptions(
        balance_kind="LUT", balance_tol=0.1, fabric=fabric,
        passes=("normalize_units", "partition", "congestion_feedback"))
    design = tapa_compile(_hot_bus_graph(), cluster, opts)
    detail = design.pass_record("congestion_feedback").detail
    assert detail["repartitioned"]
    assert design.partition.stats.method.endswith("-congested")
    assert detail["max_utilization_after"] < detail["max_utilization_before"]
    # The balanced split cut a heavy pair; the §4.3 repartition keeps the
    # pairs co-located and only the light channel crosses the bus.
    a = design.partition.assignment
    assert a["a"] == a["b"] and a["c"] == a["d"] and a["a"] != a["c"]
    assert design.congestion is not None
    assert design.congestion.max_utilization == pytest.approx(
        detail["max_utilization_after"])


def test_uniform_calibration_skips_futile_resolve():
    """With no balance band to drop, a hot bus inflates its single link's
    λ uniformly — the MILP argmin cannot change, so the pass must skip
    the re-solve instead of burning a partition solve on a no-op.  The
    hot cut is forced by Eq. 1: the two tasks cannot co-locate."""
    g = TaskGraph("forced-hot")
    for n in ("a", "b"):
        g.add_task(Task(n, ResourceProfile({"LUT": 450e3})))
    g.add_channel("a", "b", 4096, bytes_per_step=65536.0)
    cluster = Cluster(ALVEO_U55C, Bus(2))
    design = tapa_compile(g, cluster, CompileOptions(
        fabric=cluster_fabric(cluster),
        passes=("normalize_units", "partition", "congestion_feedback")))
    detail = design.pass_record("congestion_feedback").detail
    assert detail["calibration_uniform"]
    assert detail["retries"] == 0 and not detail["repartitioned"]
    assert not design.partition.stats.method.endswith("-congested")


def test_cool_fabric_does_not_repartition():
    cluster = fpga_ring_cluster(2)
    g = TaskGraph("cool")
    for n in ("x", "y"):
        g.add_task(Task(n, ResourceProfile({"LUT": 700e3})))
    g.add_channel("x", "y", 8, bytes_per_step=1.0)          # trickle
    design = tapa_compile(g, cluster, CompileOptions(
        fabric=cluster_fabric(cluster),
        passes=("normalize_units", "partition", "congestion_feedback")))
    detail = design.pass_record("congestion_feedback").detail
    assert not detail["repartitioned"]
    assert not design.partition.stats.method.endswith("-congested")


def test_calibrated_pair_cost_inflates_hot_links_only():
    cluster = fpga_ring_cluster(4)
    fab = cluster_fabric(cluster)
    g = TaskGraph("two")
    for n in ("u", "v"):
        g.add_task(Task(n, ResourceProfile({"LUT": 1.0})))
    g.add_channel("u", "v", 4096, bytes_per_step=65536.0)
    report = project(g, {"u": 0, "v": 1}, fab)   # default per-step basis
    pair = calibrated_pair_cost(fab, report, threshold=0.75)
    base = lam(ETHERNET_100G)
    hot_link = fab.route(0, 1)[0]
    assert report.link(hot_link).utilization > 0.75
    assert pair[0, 1] > base                 # inflated through the hotspot
    assert pair[2, 3] == pytest.approx(base)  # cool links untouched
    assert pair[1, 0] == pytest.approx(base)  # reverse direction is cool


# ---------------------------------------------------------------------------
# Interconnect IP resource overhead (paper §4.4, Table 10).
# ---------------------------------------------------------------------------

def _near_full_graph(frac_per_task=0.345, n=4):
    """Four tasks at ~0.345 × LUT each: 2 per device fits under T=0.70 on
    the raw die, but not once the Ethernet IP's 2.04% is carved out."""
    g = TaskGraph("nearfull")
    lut = ALVEO_U55C.resources["LUT"] * frac_per_task
    for i in range(n):
        g.add_task(Task(f"t{i}", ResourceProfile({"LUT": lut})))
    for i in range(n - 1):
        g.add_channel(f"t{i}", f"t{i+1}", 64)
    return g


def test_interconnect_overhead_rejects_near_full_device():
    g = _near_full_graph()
    charged = Cluster(ALVEO_U55C, Ring(2))
    # 2 × 0.345 = 0.690 < 0.70 × (1 - 0.0204) = 0.6857?  No: 0.690 > 0.6857
    # — infeasible once the Ethernet IP is charged...
    with pytest.raises(ILPError):
        tapa_compile(g, charged, CompileOptions(
            passes=("normalize_units", "partition")))
    # ...but feasible on the raw die (charging disabled).
    waived = Cluster(ALVEO_U55C, Ring(2), charge_interconnect_overhead=False)
    design = tapa_compile(_near_full_graph(), waived, CompileOptions(
        passes=("normalize_units", "partition")))
    assert design.partition is not None


def test_overhead_not_charged_on_single_device():
    cl = Cluster(ALVEO_U55C, Ring(1))
    assert cl.interconnect_overhead_frac("LUT") == 0.0
    cl2 = Cluster(ALVEO_U55C, Ring(3))
    assert cl2.interconnect_overhead_frac("LUT") == pytest.approx(0.0204)
    assert cl2.capacity("LUT") == pytest.approx(
        ALVEO_U55C.resources["LUT"] * (1 - 0.0204) * 0.70)


def test_overhead_with_inter_node_protocol():
    eth = Protocol("eth", 12.5e9, 1e-6, {"LUT": 0.02})
    inode = Protocol("slow", 1.25e9, 50e-6, {"LUT": 0.01})
    cl = Cluster(ALVEO_U55C, Ring(4), eth, devices_per_node=2,
                 inter_node_protocol=inode)
    assert cl.interconnect_overhead_frac("LUT") == pytest.approx(0.03)
    assert cl.interconnect_overhead_frac("DSP") == 0.0
