"""Cost model + scale-up advisor properties."""
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip

from repro.core import (ALVEO_U55C, ResourceProfile, RooflineTerms, Task,
                        TaskGraph, fpga_ring_cluster, graph_intensity,
                        lm_pod_strategy, linear_graph,
                        plan_scaleup, roofline, simulate)
# Raw implementation: the repro.core package-level name is a deprecation
# shim (use repro.compiler.compile in new code).
from repro.core.partitioner import partition


def test_roofline_dominant():
    t = roofline(hlo_flops=197e12, hlo_bytes=0, ici_bytes=0, dcn_bytes=0,
                 chips=1)
    assert t.dominant == "compute" and abs(t.compute_s - 1.0) < 1e-9
    t = roofline(hlo_flops=0, hlo_bytes=819e9, ici_bytes=0, dcn_bytes=0,
                 chips=1)
    assert t.dominant == "memory"
    t = roofline(hlo_flops=0, hlo_bytes=0, ici_bytes=50e9, dcn_bytes=0,
                 chips=1)
    assert t.dominant == "collective"


def test_dcn_more_expensive_than_ici():
    a = roofline(0, 0, ici_bytes=1e9, dcn_bytes=0, chips=1)
    b = roofline(0, 0, ici_bytes=0, dcn_bytes=1e9, chips=1)
    assert b.collective_s > a.collective_s


@settings(max_examples=20, deadline=None)
@given(st.floats(1.0, 1e4))
def test_scaleup_ridge_rule(intensity):
    """Below the device ridge → widen memory; above → replicate compute."""
    g = TaskGraph("t")
    g.add_task(Task("a", ResourceProfile({"LUT": 1.0}), hbm_bytes=1e6,
                    meta={"ops": intensity * 1e6}))
    cl = fpga_ring_cluster(4)
    plan = plan_scaleup(g, cl, 4)
    ridge = cl.device.peak_flops / cl.device.hbm_bandwidth
    if intensity < ridge:
        assert plan.mode == "widen-memory"
        assert plan.port_bits >= 512
    else:
        assert plan.mode == "replicate-compute"
        assert plan.replication > 1


def test_lm_pod_strategy_memory_gate():
    # Model state larger than a pod → pipeline parallelism.
    assert lm_pod_strategy(2e12, 0, 0, 2, 16 * 2**30, 256, 6.25e9,
                           1.0) == "pp"
    # Small model, fast step → DP only if grad traffic fits the budget.
    assert lm_pod_strategy(2e9, 0, 0, 2, 16 * 2**30, 256, 6.25e9,
                           1.0) == "dp"


def test_simulate_more_devices_not_slower_for_parallel_graph():
    """Independent tasks (KNN-like): makespan non-increasing in devices."""
    def star(n_tasks):
        g = TaskGraph("star")
        g.add_task(Task("agg", ResourceProfile({"LUT": 1.0}),
                        meta={"cycles": 10.0}))
        for i in range(n_tasks):
            g.add_task(Task(f"w{i}", ResourceProfile({"LUT": 10.0}),
                            hbm_bytes=1e9, meta={"cycles": 1e6}))
            g.add_channel(f"w{i}", "agg", 64, bytes_per_step=80.0)
        return g

    times = []
    for ndev in (1, 2, 4):
        g = star(8)
        cl = fpga_ring_cluster(ndev)
        p = partition(g, cl, balance_kind="LUT",
                      balance_tol=0.9 if ndev > 1 else 0.99)
        res = simulate(g, p, cl, {d: 300e6 for d in range(ndev)})
        times.append(res.makespan)
    assert times[2] <= times[1] <= times[0] * 1.01


def test_overlap_helps():
    g = linear_graph(4, width_bits=512, area={"LUT": 10.0})
    for i, t in enumerate(g.tasks.values()):
        t.meta["cycles"] = 1e6
    for c in g.channels:
        c.bytes_per_step = 100e6
    cl = fpga_ring_cluster(4)
    p = partition(g, cl, balance_kind="LUT", balance_tol=0.2)
    freqs = {d: 300e6 for d in range(4)}
    with_ov = simulate(g, p, cl, freqs, overlap=True)
    without = simulate(g, p, cl, freqs, overlap=False)
    assert with_ov.makespan <= without.makespan
