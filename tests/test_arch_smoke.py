"""Per-architecture smoke tests: reduced config of the same family runs one
forward/train step on CPU, asserting output shapes + no NaNs (deliverable f).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_arch
from repro.models import init_cache, init_params, serve_step, train_loss

RNG = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg):
    k1, k2 = jax.random.split(RNG)
    P = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    batch = {
        "tokens": jax.random.randint(k1, (B, S - P), 0, cfg.vocab),
        "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab),
        "weights": jnp.ones((B, S)),
    }
    if P:
        batch["frontend"] = jax.random.normal(RNG, (B, P, cfg.d_model))
    if cfg.arch == "encdec":
        batch["src"] = jax.random.normal(RNG, (B, S // 4, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch).smoke()
    params = init_params(RNG, cfg)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_arch(arch).smoke()
    params = init_params(RNG, cfg)
    cache = init_cache(cfg, B, 32)
    toks = jax.random.randint(RNG, (B, 1), 0, cfg.vocab)
    enc = (jax.random.normal(RNG, (B, 8, cfg.d_model), cfg.dtype)
           if cfg.arch == "encdec" else None)
    new_cache, logits = serve_step(params, cfg, cache, toks, jnp.int32(0),
                                   enc_out=enc)
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    # cache structure preserved
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_dims_match_assignment(arch):
    """The full() configs carry the exact published dims."""
    cfg = get_arch(arch).full()
    expected = {
        "seamless-m4t-large-v2": dict(d_model=1024, vocab=256206, L=24),
        "chatglm3-6b": dict(d_model=4096, vocab=65024, L=28),
        "mistral-nemo-12b": dict(d_model=5120, vocab=131072, L=40),
        "gemma2-27b": dict(d_model=4608, vocab=256000, L=46),
        "qwen3-4b": dict(d_model=2560, vocab=151936, L=36),
        "deepseek-v2-236b": dict(d_model=5120, vocab=102400, L=60),
        "deepseek-v3-671b": dict(d_model=7168, vocab=129280, L=61),
        "xlstm-1.3b": dict(d_model=2048, vocab=50304, L=48),
        "recurrentgemma-9b": dict(d_model=4096, vocab=256000, L=38),
        "llava-next-34b": dict(d_model=7168, vocab=64000, L=60),
    }[arch]
    assert cfg.d_model == expected["d_model"]
    assert cfg.vocab == expected["vocab"]
    assert cfg.num_layers == expected["L"]


def test_moe_dims():
    v2 = get_arch("deepseek-v2-236b").full()
    assert (v2.moe.num_experts, v2.moe.top_k, v2.moe.num_shared) == (160, 6, 2)
    assert v2.moe.d_ff_expert == 1536
    v3 = get_arch("deepseek-v3-671b").full()
    assert (v3.moe.num_experts, v3.moe.top_k, v3.moe.num_shared) == (256, 8, 1)
    assert v3.moe.d_ff_expert == 2048
    assert v3.mtp


def test_param_scale_sanity():
    """total_param_bytes tracks the published model sizes (±35%)."""
    from repro.launch.graphs import total_param_bytes
    expect_b = {"chatglm3-6b": 6e9, "mistral-nemo-12b": 12e9,
                "gemma2-27b": 27e9, "qwen3-4b": 4e9,
                "deepseek-v2-236b": 236e9, "deepseek-v3-671b": 671e9,
                "xlstm-1.3b": 1.3e9, "recurrentgemma-9b": 9e9,
                "llava-next-34b": 34e9}
    for arch, n in expect_b.items():
        cfg = get_arch(arch).full()
        got = total_param_bytes(cfg) / 2      # bf16 → param count
        assert 0.6 * n < got < 1.45 * n, (arch, got / 1e9)
