"""Property-based conservation sweep — randomized topology × traffic ×
config, one invariant suite (hypothesis when installed, else SKIPPED; the
``_fixed``-suffixed tests pin one representative case each so the
invariant bodies always run, even without hypothesis).

Invariants:

* **P1 — fabric byte conservation**: for any topology, payload set and
  NetConfig, draining the flit transport delivers every submitted byte
  and the per-link totals sum to exactly Σ payload × hops.
* **P2 — FIFO order**: messages submitted on one channel complete in
  submission order, whatever contends with them.
* **P3 — bank conservation**: for any MemConfig and any set of async
  memory channels, pumping to completion conserves bytes exactly
  (Σ per-bank bytes == Σ channel-delivered == Σ requested), responses
  arrive per-channel FIFO, and measured utilization never exceeds 1.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st  # noqa: E402

from repro.core import Bus, DaisyChain, Hypercube, Mesh2D, Ring, Star
from repro.mem import AsyncMemChannel, MemConfig, MemorySystem, measure
from repro.net import FabricTransport, NetConfig, build_fabric

_TOPOS = [DaisyChain(3), Ring(4), Ring(5), Bus(3), Star(4),
          Mesh2D(2, 3), Hypercube(3)]


def _net_cfg(mtu, credits, budget_flits):
    # sweep_time sized so one link moves `budget_flits` flits per sweep.
    from repro.core.topology import ETHERNET_100G
    bw = ETHERNET_100G.bandwidth_Bps
    return NetConfig(mtu_bytes=mtu, link_credits=credits,
                     sweep_time_s=(budget_flits * mtu) / bw)


# ---------------------------------------------------------------------------
# P1 + P2 — fabric conservation and per-channel FIFO.
# ---------------------------------------------------------------------------

def check_fabric_conservation(topo_idx, payloads, mtu, credits, budget):
    """The invariant body (plain function: runs under hypothesis or
    pinned).  ``payloads`` is [(src, dst, nbytes)] — one channel each."""
    topo = _TOPOS[topo_idx % len(_TOPOS)]
    n = topo.num_devices
    fab = build_fabric(topo)
    tr = FabricTransport(fab, _net_cfg(mtu, credits, budget))
    routed = []
    for ch, (s, d, nb) in enumerate(payloads):
        s, d = s % n, d % n
        if s == d:
            continue
        tr.submit(ch, s, d, nb, 0)
        routed.append((s, d, nb))
    done = tr.drain(0)
    # P1: every byte delivered; per-link totals == Σ bytes × hops, exactly.
    assert tr.total_delivered_bytes == sum(nb for _, _, nb in routed)
    assert sum(c.bytes for c in tr.counters) == \
        sum(nb * fab.hops(s, d) for s, d, nb in routed)
    assert sum(c.flits for c in tr.counters) == sum(
        tr.config.flits_for(nb) * fab.hops(s, d) for s, d, nb in routed)
    assert len(done) == len(routed)
    for li in range(len(fab.links)):
        assert tr.utilization(li) <= 1.0 + 1e-12


def check_fifo_order(n_msgs, sizes, mtu, budget):
    """P2: one channel's messages complete in submission order even while
    a rival channel contends for the same links."""
    fab = build_fabric(DaisyChain(3))
    tr = FabricTransport(fab, _net_cfg(mtu, 4, budget))
    for i in range(n_msgs):
        tr.submit(0, 0, 2, sizes[i % len(sizes)], 0)   # the watched channel
        tr.submit(1, 1, 2, sizes[(i + 1) % len(sizes)], 0)  # the rival
    done = tr.drain(0)
    watched = [mid for mid, ch in done if ch == 0]
    assert watched == sorted(watched), "channel 0 responses out of order"
    assert len(watched) == n_msgs


@settings(max_examples=25, deadline=None)
@given(topo_idx=st.integers(min_value=0, max_value=len(_TOPOS) - 1),
       payloads=st.lists(
           st.tuples(st.integers(min_value=0, max_value=7),
                     st.integers(min_value=0, max_value=7),
                     st.integers(min_value=1, max_value=5000)),
           min_size=1, max_size=8),
       mtu=st.sampled_from([32, 64, 100, 256]),
       credits=st.integers(min_value=1, max_value=6),
       budget=st.integers(min_value=1, max_value=4))
def test_fabric_conservation_property(topo_idx, payloads, mtu, credits,
                                      budget):
    check_fabric_conservation(topo_idx, payloads, mtu, credits, budget)


@settings(max_examples=25, deadline=None)
@given(n_msgs=st.integers(min_value=1, max_value=6),
       sizes=st.lists(st.integers(min_value=1, max_value=1000),
                      min_size=1, max_size=4),
       mtu=st.sampled_from([32, 64, 128]),
       budget=st.integers(min_value=1, max_value=3))
def test_fifo_order_property(n_msgs, sizes, mtu, budget):
    check_fifo_order(n_msgs, sizes, mtu, budget)


def test_fabric_conservation_fixed():
    check_fabric_conservation(1, [(0, 2, 1234), (1, 3, 999), (3, 0, 100),
                                  (2, 2, 64)], 100, 4, 2)
    check_fabric_conservation(3, [(0, 1, 1), (2, 0, 4999)], 32, 1, 1)


def test_fifo_order_fixed():
    check_fifo_order(5, [1000, 64, 333], 64, 2)


# ---------------------------------------------------------------------------
# P3 — bank conservation through async memory channels.
# ---------------------------------------------------------------------------

def check_bank_conservation(bpd, bandwidth_MBps, credits, burst,
                            chan_specs, count):
    """``chan_specs`` is [(device, bank, token_elems)]; every channel
    fetches ``count`` float32 tokens of its given size."""
    import jax.numpy as jnp

    cfg = MemConfig(banks_per_device=bpd,
                    bank_bandwidth_Bps=bandwidth_MBps * 1e6,
                    credits=credits, burst_bytes=burst)
    ndev = max(d for d, _, _ in chan_specs) + 1
    ms = MemorySystem(ndev, cfg)
    chans = []
    for ci, (dev, bank, elems) in enumerate(chan_specs):
        toks = [jnp.full((elems,), float(ci * 100 + t))
                for t in range(count)]
        chans.append(AsyncMemChannel(ci, f"t{ci}", "x", toks, count,
                                     device=dev, bank=bank, memsys=ms))
    got = {ci: [] for ci in range(len(chans))}
    sweep = 0
    while any(c.stats.consumed < c.count for c in chans):
        for c in chans:
            c.pump(sweep)
        for c in chans:
            if c.stats.consumed < c.count and c.response_ready(sweep):
                got[c.index].append(c.consume(sweep))
        for rid, ci in ms.step(sweep):
            chans[ci].on_complete(rid, sweep)
        sweep += 1
        assert sweep < 50_000, "memory system failed to make progress"
    # Conservation: requested == delivered == Σ per-bank served bytes.
    req = sum(c.stats.requested_bytes for c in chans)
    dlv = sum(c.stats.delivered_bytes for c in chans)
    assert req == dlv == ms.total_served_bytes == ms.total_requested_bytes
    assert sum(b.bytes for b in measure(ms).banks) == dlv
    for b in range(ndev * bpd):
        assert ms.utilization(b) <= 1.0 + 1e-12
    # FIFO: every channel saw its tokens in issue order, bit-exact.
    for c in chans:
        assert c.stats.max_outstanding <= cfg.credits
        for t, tok in enumerate(got[c.index]):
            assert float(tok[0]) == float(c.index * 100 + t)


@settings(max_examples=25, deadline=None)
@given(bpd=st.integers(min_value=1, max_value=4),
       bandwidth_MBps=st.sampled_from([32, 64, 256]),
       credits=st.integers(min_value=1, max_value=6),
       burst=st.sampled_from([32, 64, 256]),
       chan_specs=st.lists(
           st.tuples(st.integers(min_value=0, max_value=2),
                     st.integers(min_value=0, max_value=7),
                     st.integers(min_value=1, max_value=96)),
           min_size=1, max_size=6),
       count=st.integers(min_value=1, max_value=5))
def test_bank_conservation_property(bpd, bandwidth_MBps, credits, burst,
                                    chan_specs, count):
    check_bank_conservation(bpd, bandwidth_MBps, credits, burst,
                            chan_specs, count)


def test_bank_conservation_fixed():
    # Two channels contending on one bank + a third on its own device.
    check_bank_conservation(2, 64, 2, 64,
                            [(0, 0, 48), (0, 0, 16), (1, 1, 96)], 3)
    check_bank_conservation(1, 32, 1, 32, [(0, 0, 1)], 1)


# ---------------------------------------------------------------------------
# P4 — trace-event byte conservation (repro.obs): over random topology ×
# traffic × fault configs, summed trace-event bytes equal the per-link
# goodput/retransmit counters and the per-bank byte counters, as exact
# integers (no tolerance) — the route-repair reclassification included.
# ---------------------------------------------------------------------------

def check_trace_net_conservation(topo_idx, payloads, mtu, credits, budget,
                                 drop, corrupt, seed, down=()):
    from repro.net.faults import FaultModel, LinkFaults
    from repro.obs.trace import (Tracer, to_chrome_trace,
                                 validate_chrome_trace)
    topo = _TOPOS[topo_idx % len(_TOPOS)]
    n = topo.num_devices
    fab = build_fabric(topo)
    fm = None
    if drop or corrupt or down:
        # fail_threshold=None: lossy links retry forever instead of dying,
        # so any topology (including cut-through chains) stays routable.
        fm = FaultModel(seed=seed,
                        default=LinkFaults(drop=drop, corrupt=corrupt,
                                           down=tuple(down)),
                        fail_threshold=None)
    tracer = Tracer()
    tr = FabricTransport(fab, _net_cfg(mtu, credits, budget), faults=fm,
                         tracer=tracer)
    submitted = 0
    for ch, (s, d, nb) in enumerate(payloads):
        s, d = s % n, d % n
        if s == d:
            continue
        tr.submit(ch, s, d, nb, 0)
        submitted += nb
    tr.drain(0)
    assert tr.total_delivered_bytes == submitted
    # Per-link, exact ints: Σ flit_hop − Σ flit_reclassify == goodput,
    # Σ retransmit + Σ flit_reclassify == wasted wire bytes.
    goodput = tracer.link_goodput_bytes()
    retx = {}
    for e in tracer.iter_kind("retransmit"):
        retx[e[2]] = retx.get(e[2], 0) + e[3]
    for e in tracer.iter_kind("flit_reclassify"):
        retx[e[2]] = retx.get(e[2], 0) + e[3]
    for li, c in enumerate(tr.counters):
        assert goodput.get(li, 0) == int(c.bytes), f"link {li} goodput"
        assert retx.get(li, 0) == int(c.retransmit_bytes), \
            f"link {li} retransmit"
    validate_chrome_trace(to_chrome_trace(tracer))


def check_trace_bank_conservation(bpd, bandwidth_MBps, credits, burst,
                                  chan_specs, count):
    import jax.numpy as jnp

    from repro.obs.trace import Tracer

    cfg = MemConfig(banks_per_device=bpd,
                    bank_bandwidth_Bps=bandwidth_MBps * 1e6,
                    credits=credits, burst_bytes=burst)
    ndev = max(d for d, _, _ in chan_specs) + 1
    tracer = Tracer()
    ms = MemorySystem(ndev, cfg, tracer=tracer)
    chans = []
    for ci, (dev, bank, elems) in enumerate(chan_specs):
        toks = [jnp.full((elems,), float(ci * 100 + t))
                for t in range(count)]
        chans.append(AsyncMemChannel(ci, f"t{ci}", "x", toks, count,
                                     device=dev, bank=bank, memsys=ms,
                                     tracer=tracer))
    sweep = 0
    while any(c.stats.consumed < c.count for c in chans):
        for c in chans:
            c.pump(sweep)
        for c in chans:
            if c.stats.consumed < c.count and c.response_ready(sweep):
                c.consume(sweep)
        for rid, ci in ms.step(sweep):
            chans[ci].on_complete(rid, sweep)
        sweep += 1
        assert sweep < 50_000, "memory system failed to make progress"
    # Per-bank, exact ints: Σ bank_burst bytes == served bytes; one
    # mem_issue event per issued request carrying the requested bytes.
    bank_bytes = tracer.bank_bytes()
    for b in range(ndev * bpd):
        assert bank_bytes.get(b, 0) == int(ms.counters[b].bytes), \
            f"bank {b} bytes"
    issues = list(tracer.iter_kind("mem_issue"))
    assert len(issues) == sum(c.stats.issued for c in chans)
    assert sum(e[6] for e in issues) == \
        sum(c.stats.requested_bytes for c in chans)


@settings(max_examples=25, deadline=None)
@given(topo_idx=st.integers(min_value=0, max_value=len(_TOPOS) - 1),
       payloads=st.lists(
           st.tuples(st.integers(min_value=0, max_value=7),
                     st.integers(min_value=0, max_value=7),
                     st.integers(min_value=1, max_value=5000)),
           min_size=1, max_size=6),
       mtu=st.sampled_from([32, 64, 256]),
       credits=st.integers(min_value=1, max_value=6),
       budget=st.integers(min_value=1, max_value=4),
       drop=st.sampled_from([0.0, 0.1, 0.3]),
       corrupt=st.sampled_from([0.0, 0.1]),
       seed=st.integers(min_value=0, max_value=999),
       down=st.sampled_from([(), ((0, 3),), ((2, 6),)]))
def test_trace_net_conservation_property(topo_idx, payloads, mtu, credits,
                                         budget, drop, corrupt, seed, down):
    check_trace_net_conservation(topo_idx, payloads, mtu, credits, budget,
                                 drop, corrupt, seed, down)


@settings(max_examples=25, deadline=None)
@given(bpd=st.integers(min_value=1, max_value=4),
       bandwidth_MBps=st.sampled_from([32, 64, 256]),
       credits=st.integers(min_value=1, max_value=6),
       burst=st.sampled_from([32, 64, 256]),
       chan_specs=st.lists(
           st.tuples(st.integers(min_value=0, max_value=2),
                     st.integers(min_value=0, max_value=7),
                     st.integers(min_value=1, max_value=96)),
           min_size=1, max_size=6),
       count=st.integers(min_value=1, max_value=5))
def test_trace_bank_conservation_property(bpd, bandwidth_MBps, credits,
                                          burst, chan_specs, count):
    check_trace_bank_conservation(bpd, bandwidth_MBps, credits, burst,
                                  chan_specs, count)


def test_trace_net_conservation_fixed():
    check_trace_net_conservation(1, [(0, 2, 1234), (1, 3, 999),
                                     (3, 0, 100)], 100, 4, 2,
                                 0.0, 0.0, 0)
    check_trace_net_conservation(1, [(0, 2, 2000), (2, 0, 4999)], 32, 2, 1,
                                 0.3, 0.1, 7, down=((0, 3),))


def test_trace_net_conservation_link_death_reclassifies():
    """A permanent link death mid-transfer forces route repair: the
    reclassified crossings keep both trace identities exact on a ring."""
    from repro.net.faults import FaultModel, LinkFaults
    from repro.obs.trace import Tracer
    fab = build_fabric(Ring(4))
    dead = {li for li, l in enumerate(fab.links)
            if (l.src, l.dst) == (0, 1)}
    fm = FaultModel(seed=3,
                    links={li: LinkFaults(down=((2, None),))
                           for li in dead},
                    fail_threshold=3)
    tracer = Tracer()
    tr = FabricTransport(fab, _net_cfg(64, 2, 1), faults=fm, tracer=tracer)
    tr.submit(0, 0, 1, 4000, 0)
    tr.drain(0)
    assert tr.total_delivered_bytes == 4000
    assert tracer.count("link_death") >= 1
    assert tracer.count("reroute") >= 1
    goodput = tracer.link_goodput_bytes()
    retx = {}
    for e in tracer.iter_kind("retransmit"):
        retx[e[2]] = retx.get(e[2], 0) + e[3]
    for e in tracer.iter_kind("flit_reclassify"):
        retx[e[2]] = retx.get(e[2], 0) + e[3]
    for li, c in enumerate(tr.counters):
        assert goodput.get(li, 0) == int(c.bytes)
        assert retx.get(li, 0) == int(c.retransmit_bytes)


def test_trace_bank_conservation_fixed():
    check_trace_bank_conservation(2, 64, 2, 64,
                                  [(0, 0, 48), (0, 0, 16), (1, 1, 96)], 3)
    check_trace_bank_conservation(1, 32, 1, 32, [(0, 0, 1)], 1)


# ---------------------------------------------------------------------------
# P5 — per-flow fault attribution (repro.obs.attrib substrate): on a lossy
# link shared by two tenant flows with weights w:1, (a) the per-flow fault
# dictionaries sum exactly to the global link counters at every point, and
# (b) wire-service attempts (goodput + retransmissions — the quantity the
# cost ledger charges) split by DRR weight within the scheduler's ±2-flit
# deficit tolerance while both flows are backlogged.  And killing one flow
# charges its peer exactly zero fault cost.
# ---------------------------------------------------------------------------

def check_weighted_fault_attribution(seed, w, drop):
    """Two flows, weights w:1, one lossy shared link.  Many 1-flit
    messages keep both flows backlogged so neither forfeits its DRR
    deficit; the attempt split is sampled at the first sweep with >= 30
    attempts (both still backlogged), the exact per-flow conservation
    identities at drain."""
    from repro.net.faults import FaultModel, LinkFaults
    fab = build_fabric(DaisyChain(2))
    fm = FaultModel(seed=seed, default=LinkFaults(drop=drop),
                    fail_threshold=None, backoff_base=1, backoff_cap=1)
    tr = FabricTransport(fab, _net_cfg(64, 8, 2),
                         flow_weights={0: float(w), 1: 1.0}, faults=fm)
    msgs = 24                      # 1 flit each — per-message blocking
    for i in range(msgs):          # can't skew the arbiter
        tr.submit(0, 0, 1, 64, 0, flow=0)
        tr.submit(1, 0, 1, 64, 0, flow=1)
    link = fab.route(0, 1)[0]
    sweep, snap = 0, None
    while tr.active:
        tr.step(sweep)
        c = tr.counters[link]
        if snap is None and c.attempt_flits >= 30:
            att = {f: c.flow_flits.get(f, 0)
                   + c.flow_retransmit_flits.get(f, 0) for f in (0, 1)}
            snap = att
        sweep += 1
        assert sweep < 100_000, "lossy link failed to drain"
    assert tr.total_delivered_bytes == 2 * msgs * 64
    # (a) exact per-flow conservation, every fault column, every link.
    for c in tr.counters:
        assert sum(c.flow_bytes.values()) == c.bytes
        assert sum(c.flow_flits.values()) == c.flits
        assert sum(c.flow_retransmit_bytes.values()) == c.retransmit_bytes
        assert sum(c.flow_retransmit_flits.values()) == c.retransmit_flits
        assert sum(c.flow_backoff_sweeps.values()) == c.backoff_sweeps
        assert sum(c.flow_arq_stalls.values()) == c.arq_stalls
    # (b) weighted split of wire attempts, ±2 flits (DRR deficit bound).
    assert snap is not None, "snapshot threshold never reached"
    total = snap[0] + snap[1]
    expected_light = total * (1.0 / (w + 1.0))
    assert abs(snap[1] - expected_light) <= 2, \
        f"w={w} drop={drop} seed={seed}: attempts {snap} vs " \
        f"expected light share {expected_light:.1f}"


def check_kill_peer_zero_charge(topo_idx, kill_after, nbytes, mtu, credits):
    """Cancelling one flow mid-flight (the transport half of a tenant
    kill) charges every cancelled byte to that flow and exactly nothing —
    no fault column at all — to the surviving peer."""
    topo = _TOPOS[topo_idx % len(_TOPOS)]
    fab = build_fabric(topo)
    tr = FabricTransport(fab, _net_cfg(mtu, credits, 2),
                         flow_weights={0: 1.0, 1: 1.0})
    tr.submit(0, 0, 1, nbytes, 0, flow=0)      # the victim
    tr.submit(1, 0, 1, nbytes, 0, flow=1)      # the peer
    sweep, done = 0, []
    while sweep < kill_after and tr.active:
        done += tr.step(sweep)
        sweep += 1
    tr.cancel_flow(0)
    while tr.active:
        done += tr.step(sweep)
        sweep += 1
        assert sweep < 100_000
    # Cancelled bytes land on the victim only; totals stay exact.
    assert tr.cancelled_flow_bytes.get(1, 0) == 0
    assert sum(tr.cancelled_flow_bytes.values()) == tr.cancelled_bytes
    # The peer's fault ledger is exactly zero in every column.
    peer = tr.flow_fault_totals(1)
    assert peer == {"retransmit_bytes": 0, "retransmit_flits": 0,
                    "backoff_sweeps": 0, "arq_stalls": 0}
    # The peer's message still completed despite the mid-flight kill.
    assert any(ch == 1 for _mid, ch in done)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=999),
       w=st.sampled_from([1, 2, 3]),
       drop=st.sampled_from([0.0, 0.05, 0.1, 0.2]))
def test_weighted_fault_attribution_property(seed, w, drop):
    check_weighted_fault_attribution(seed, w, drop)


@settings(max_examples=25, deadline=None)
@given(topo_idx=st.integers(min_value=0, max_value=len(_TOPOS) - 1),
       kill_after=st.integers(min_value=0, max_value=6),
       nbytes=st.integers(min_value=1, max_value=5000),
       mtu=st.sampled_from([32, 64, 256]),
       credits=st.integers(min_value=1, max_value=6))
def test_kill_peer_zero_charge_property(topo_idx, kill_after, nbytes, mtu,
                                        credits):
    check_kill_peer_zero_charge(topo_idx, kill_after, nbytes, mtu, credits)


def test_weighted_fault_attribution_fixed():
    check_weighted_fault_attribution(3, 2, 0.1)
    check_weighted_fault_attribution(7, 3, 0.2)
    check_weighted_fault_attribution(0, 1, 0.0)


def test_kill_peer_zero_charge_fixed():
    check_kill_peer_zero_charge(1, 3, 4000, 64, 2)
    check_kill_peer_zero_charge(0, 0, 1, 32, 1)


def test_hypothesis_shim_declares_itself():
    """The compat import must resolve either way — and when hypothesis is
    absent the @given tests above report SKIPPED, not errors."""
    assert HAVE_HYPOTHESIS in (True, False)
