"""GPipe-style pod-axis pipeline (launch/pipeline.py): correctness vs
sequential stage application, on an 8-device fake mesh (subprocess — device
count locks at first jax init)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.launch.pipeline import gpipe_forward

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    Pst, D = 2, 16
    rng = jax.random.PRNGKey(0)
    w = jax.random.normal(rng, (Pst, D, D)) * 0.3
    x = jax.random.normal(jax.random.fold_in(rng, 1), (8, D))

    def stage(wi, xb):
        return jnp.tanh(xb @ wi)

    w_sh = jax.device_put(w, NamedSharding(mesh, P("pod")))
    x_sh = jax.device_put(x, NamedSharding(mesh, P("data")))
    with mesh:
        for M in (1, 2, 4):
            y = jax.jit(lambda w, x, M=M: gpipe_forward(
                stage, w, x, mesh, microbatches=M))(w_sh, x_sh)
            ref = x
            for i in range(Pst):
                ref = stage(w[i], ref)
            err = float(jnp.max(jnp.abs(np.asarray(y) - np.asarray(ref))))
            assert err < 1e-5, (M, err)
    print("PIPELINE_OK")
""")


def test_gpipe_matches_sequential():
    env = dict(os.environ)
    ambient = env.get("PYTHONPATH")
    env["PYTHONPATH"] = "src" + (os.pathsep + ambient if ambient else "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-2000:]
    assert "PIPELINE_OK" in res.stdout
