"""HLO collective parser: shapes, trip-count multipliers, DCN classification."""
import numpy as np
import pytest

from repro.launch.hlo_analysis import (CollectiveOp, collective_bytes,
                                       cpu_bf16_convert_bytes,
                                       parse_collectives, _is_dcn)

HLO = """
  %all-gather = f32[32,256]{0,1} all-gather(%copy), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}, metadata={op_name="jit(f)/while/body/dot_general"}
  %all-reduce.1 = bf16[1024]{0} all-reduce(%x), channel_id=2, replica_groups={{0,256},{1,257}}, metadata={op_name="jit(f)/reduce_sum"}
  %all-reduce.2 = f32[16,512,151936]{2,1,0} all-reduce(%y), channel_id=3, replica_groups=[16,16]<=[256], metadata={op_name="jit(f)/while/body/logsumexp"}
  %collective-permute = bf16[8,128]{1,0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}, metadata={op_name="jit(f)/ppermute"}
  %all-to-all-start = f32[64,64]{1,0} all-to-all(%w), channel_id=5, replica_groups=[4,2]<=[2,4]T(1,0), metadata={op_name="jit(f)/while/body/while/body/a2a"}
"""


def test_parse_finds_all():
    ops = parse_collectives(HLO, num_superblocks=10, seq_len=4096,
                            vocab=151936, chips_per_pod=256)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-reduce",
                     "all-to-all", "collective-permute"]


def test_trip_count_multipliers():
    ops = parse_collectives(HLO, num_superblocks=10, seq_len=4096,
                            vocab=151936, chips_per_pod=256, inner_trip=4)
    by_kind = {(o.kind, o.while_depth): o for o in ops}
    assert by_kind[("all-gather", 1)].trip_mult == 10       # layer scan
    assert by_kind[("all-reduce", 0)].trip_mult == 1        # top level
    # vocab-sized op inside a while → xent chunk count = 4096/512
    vocab_op = [o for o in ops if 151936 in o.shape][0]
    assert vocab_op.trip_mult == 8
    # depth-2 op → superblocks × inner
    assert by_kind[("all-to-all", 2)].trip_mult == 40


def test_dcn_classification():
    # explicit groups mixing pods
    assert _is_dcn("replica_groups={{0,256},{1,257}}", 256)
    assert not _is_dcn("replica_groups={{0,1},{2,3}}", 256)
    # iota covering 512 devices with stride-256 partners (pod axis)
    assert _is_dcn("replica_groups=[256,2]<=[2,256]T(1,0)", 256)
    # iota within one pod
    assert not _is_dcn("replica_groups=[2,4]<=[8]", 256)


def test_collective_byte_aggregation():
    ops = [CollectiveOp("all-reduce", "f32", (100,), 400.0, 0, 2.0, False,
                        ""),
           CollectiveOp("all-gather", "bf16", (100,), 200.0, 0, 1.0, True,
                        "")]
    agg = collective_bytes(ops)
    assert agg["ici"] == 400.0 * 2 * 2          # mult × all-reduce factor
    assert agg["dcn"] == 200.0


def test_cpu_convert_detection():
    txt = """
%wrapped_convert_computation (param_0.185: bf16[60,8,2048,8,128]) -> f32[60,8,2048,8,128] {
%other (param_0: bf16[4,4]) -> f32[4,4] {
"""
    got = cpu_bf16_convert_bytes(txt)
    assert got == 60 * 8 * 2048 * 8 * 128 * 4   # big one only
