"""runtime.fault — failure injection, restart supervision, stragglers.

Direct unit coverage for the three fault-tolerance primitives: the
deterministic :class:`FailureInjector` (fires each scheduled step exactly
once), the :func:`run_with_restarts` supervisor (restart counting, success
after k failures, exhaustion), and the :class:`StragglerMonitor` EWMA
detector driven by a scripted clock so its flagging is deterministic.
"""
import numpy as np
import pytest

from repro.runtime import fault
from repro.runtime.fault import (FailureInjector, StragglerMonitor,
                                 backoff_delay,
                                 run_with_restarts)


# ---------------------------------------------------------------------------
# FailureInjector
# ---------------------------------------------------------------------------

def test_injector_fires_each_scheduled_step_once():
    inj = FailureInjector(fail_at_steps=[2, 5])
    fired = []
    for step in range(8):
        try:
            inj.check(step)
        except FailureInjector.Injected:
            fired.append(step)
    assert fired == [2, 5]
    # A restarted run re-traverses the same steps: no double fire.
    for step in range(8):
        inj.check(step)
    assert inj.fired == {2, 5}


def test_injector_default_is_inert():
    inj = FailureInjector()
    for step in range(100):
        inj.check(step)
    assert not inj.fired


# ---------------------------------------------------------------------------
# run_with_restarts
# ---------------------------------------------------------------------------

def _flaky_run(inj, total_steps=10):
    """A trainer stand-in: resumes from the step after the last failure
    (the checkpoint contract) and returns the final step reached."""
    attempts = []

    def make_and_run(attempt):
        attempts.append(attempt)
        start = max(inj.fired, default=-1) + 1
        for step in range(start, total_steps):
            inj.check(step)
        return total_steps - 1

    return make_and_run, attempts


def test_restarts_count_and_recover():
    inj = FailureInjector(fail_at_steps=[1, 4, 7])
    make_and_run, attempts = _flaky_run(inj)
    assert run_with_restarts(make_and_run, max_restarts=5) == 9
    # One initial attempt + exactly one restart per injected failure.
    assert attempts == [0, 1, 2, 3]


def test_restarts_exhaust_with_diagnostic():
    inj = FailureInjector(fail_at_steps=[0, 1, 2, 3, 4])
    make_and_run, attempts = _flaky_run(inj)
    with pytest.raises(RuntimeError, match="exhausted 2 restarts"):
        run_with_restarts(make_and_run, max_restarts=2)
    assert attempts == [0, 1, 2]         # initial + the two allowed restarts

    # The same failure schedule succeeds when the budget covers it.
    inj2 = FailureInjector(fail_at_steps=[0, 1, 2, 3, 4])
    make_and_run2, _ = _flaky_run(inj2)
    assert run_with_restarts(make_and_run2, max_restarts=5) == 9


def test_supervisor_only_catches_injected_faults():
    def broken(attempt):
        raise ValueError("a real bug, not a fault")
    with pytest.raises(ValueError, match="real bug"):
        run_with_restarts(broken, max_restarts=3)


# ---------------------------------------------------------------------------
# backoff_delay: capped exponential restart pacing, seeded jitter.
# ---------------------------------------------------------------------------

def test_backoff_doubles_then_caps():
    # Jitter off: the schedule is exact — 1, 2, 4, 8, ..., capped at 30.
    delays = [backoff_delay(n, base_s=1.0, cap_s=30.0, jitter=0.0)
              for n in range(1, 9)]
    assert delays == [1.0, 2.0, 4.0, 8.0, 16.0, 30.0, 30.0, 30.0]
    # Huge attempt counts must not overflow the shift.
    assert backoff_delay(10_000, base_s=1.0, cap_s=30.0, jitter=0.0) == 30.0
    with pytest.raises(ValueError):
        backoff_delay(0, base_s=1.0)


def test_backoff_jitter_is_bounded_and_seeded():
    rng1 = np.random.default_rng(5)
    rng2 = np.random.default_rng(5)
    seen = []
    for n in range(1, 6):
        d1 = backoff_delay(n, base_s=1.0, cap_s=30.0, jitter=0.1, rng=rng1)
        d2 = backoff_delay(n, base_s=1.0, cap_s=30.0, jitter=0.1, rng=rng2)
        assert d1 == d2                      # same seed: same schedule
        nominal = min(30.0, 2.0 ** (n - 1))
        assert 0.9 * nominal <= d1 <= 1.1 * nominal
        seen.append(d1)
    assert seen != [min(30.0, 2.0 ** (n - 1)) for n in range(1, 6)]
    # jitter without an rng keeps the schedule exact (no hidden global rng).
    assert backoff_delay(3, base_s=1.0, jitter=0.5) == 4.0


def test_supervisor_backoff_schedule_without_real_sleep():
    """The supervisor's restart pacing is assertable with an injected
    sleep — no wall time passes, the schedule is the capped-exponential
    one, and backoff_s=0 (legacy) never calls sleep at all."""
    slept = []
    inj = FailureInjector(fail_at_steps=[0, 1, 2, 3])
    make_and_run, _ = _flaky_run(inj)
    run_with_restarts(make_and_run, max_restarts=5, backoff_s=1.0,
                      backoff_cap_s=4.0, jitter=0.0, sleep=slept.append)
    assert slept == [1.0, 2.0, 4.0, 4.0]     # doubling, then the cap

    slept2 = []
    inj2 = FailureInjector(fail_at_steps=[0, 1])
    make_and_run2, _ = _flaky_run(inj2)
    run_with_restarts(make_and_run2, max_restarts=5, backoff_s=0.0,
                      sleep=slept2.append)
    assert slept2 == []                      # legacy hot restart


def test_supervisor_backoff_jitter_reproducible_by_seed():
    def schedule(seed):
        slept = []
        inj = FailureInjector(fail_at_steps=[0, 1, 2])
        make_and_run, _ = _flaky_run(inj)
        run_with_restarts(make_and_run, max_restarts=5, backoff_s=1.0,
                          backoff_cap_s=8.0, jitter=0.25, seed=seed,
                          sleep=slept.append)
        return slept
    assert schedule(3) == schedule(3)
    assert schedule(3) != schedule(4)
    for d, nominal in zip(schedule(3), [1.0, 2.0, 4.0]):
        assert 0.75 * nominal <= d <= 1.25 * nominal


# ---------------------------------------------------------------------------
# StragglerMonitor (scripted clock → deterministic flags)
# ---------------------------------------------------------------------------

def _scripted_clock(monkeypatch, durations):
    """perf_counter values yielding the given per-step durations for the
    start/stop call pairs the monitor makes."""
    ticks = [0.0]
    for d in durations:
        ticks.append(ticks[-1] + d)       # value at stop()
        ticks.append(ticks[-1])           # value at next start()
    it = iter(ticks)
    monkeypatch.setattr(fault.time, "perf_counter", lambda: next(it))


def test_straggler_flags_only_the_slow_step(monkeypatch):
    # Steady 1.0 s steps, one 3.0 s straggler: 3.0 > 2.5 × ewma(≈1.0).
    durations = [1.0, 1.0, 1.0, 3.0, 1.0]
    _scripted_clock(monkeypatch, durations)
    mon = StragglerMonitor(alpha=0.1, threshold=2.5)
    slow = []
    for step, _ in enumerate(durations):
        mon.start()
        if mon.stop(step):
            slow.append(step)
    assert slow == [3]
    assert mon.flagged == [3]


def test_straggler_first_step_never_flags(monkeypatch):
    # No EWMA baseline yet: even a huge first step cannot be a straggler.
    _scripted_clock(monkeypatch, [100.0, 1.0])
    mon = StragglerMonitor()
    mon.start()
    assert not mon.stop(0)
    # ...and it poisons the baseline high: the next fast step is also fine.
    mon.start()
    assert not mon.stop(1)
    assert mon.flagged == []


def test_straggler_ewma_adapts(monkeypatch):
    """A permanent slowdown is flagged once, then absorbed into the mean —
    the monitor tracks drift instead of flagging forever."""
    durations = [1.0] * 3 + [4.0] * 30
    _scripted_clock(monkeypatch, durations)
    mon = StragglerMonitor(alpha=0.5, threshold=2.5)
    for step, _ in enumerate(durations):
        mon.start()
        mon.stop(step)
    assert mon.flagged == [3]            # the jump itself
    assert mon.ewma == pytest.approx(4.0, rel=1e-3)  # ...then adapted
