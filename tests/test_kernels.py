"""Pallas kernel sweeps vs pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.knn.ops import knn_op, knn_ref
from repro.kernels.stencil_dilate.ops import dilate_iters_ref, dilate_op
from repro.kernels.systolic_matmul.ops import (conv_im2col_ref, conv_op,
                                               matmul_op, matmul_ref)

RNG = jax.random.PRNGKey(0)


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("B,H,K,Sq,Sk,d", [
    (1, 2, 2, 128, 128, 64),
    (2, 4, 2, 64, 64, 32),       # GQA
    (1, 8, 1, 128, 128, 64),     # MQA
    (1, 2, 2, 64, 256, 64),      # decode-style Sq<Sk
    (1, 2, 2, 100, 200, 64),     # unaligned → pad path
])
def test_flash_attention_shapes(B, H, K, Sq, Sk, d):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, K, Sk, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, K, Sk, d), jnp.float32)
    out = flash_attention_op(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kwargs", [
    {"window": 32}, {"softcap": 50.0}, {"causal": False},
    {"window": 64, "softcap": 30.0},
])
def test_flash_attention_features(kwargs):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.float32)
    out = flash_attention_op(q, k, v, block_q=64, block_k=64, **kwargs)
    ref = attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
    out = flash_attention_op(q, k, v, block_q=64, block_k=64)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), atol=2e-2, rtol=2e-2)


# -- stencil ------------------------------------------------------------------

@pytest.mark.parametrize("h,w,iters,br", [
    (256, 128, 1, 64), (256, 128, 3, 128), (128, 256, 2, 128),
    (512, 128, 1, 256),
])
def test_dilate(h, w, iters, br):
    img = jax.random.normal(RNG, (h, w), jnp.float32)
    out = dilate_op(img, iters=iters, block_rows=br)
    ref = dilate_iters_ref(img, iters)
    np.testing.assert_allclose(out, ref)


def test_dilate_monotone():
    img = jax.random.normal(RNG, (128, 128), jnp.float32)
    out = dilate_op(img, iters=1, block_rows=64)
    assert bool(jnp.all(out >= img))          # dilation never shrinks


# -- knn ----------------------------------------------------------------------

@pytest.mark.parametrize("Q,N,D,k", [
    (32, 500, 8, 5), (64, 1000, 16, 10), (16, 2048, 2, 10),
    (33, 999, 32, 10),                        # unaligned
])
def test_knn(Q, N, D, k):
    q = jax.random.normal(RNG, (Q, D), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(RNG, 1), (N, D), jnp.float32)
    d, i = knn_op(q, x, k=k, block_q=32, block_n=256)
    dr, ir = knn_ref(q, x, k)
    np.testing.assert_allclose(d, dr, atol=1e-4, rtol=1e-4)
    # Indices may permute among ties — compare distances gathered by index.
    gathered = jnp.sum((q[:, None, :] - x[i]) ** 2, -1)
    np.testing.assert_allclose(gathered, dr, atol=1e-4, rtol=1e-4)


# -- systolic matmul ----------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (256, 256, 256, 128, 128, 128),
    (300, 200, 150, 128, 128, 64),            # unaligned
    (64, 512, 64, 64, 64, 256),
])
def test_matmul(M, K, N, bm, bn, bk):
    a = jax.random.normal(RNG, (M, K), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(RNG, 2), (K, N), jnp.float32)
    out = matmul_op(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(out, matmul_ref(a, b), atol=1e-3, rtol=1e-4)


def test_conv_vgg_style():
    x = jax.random.normal(RNG, (16, 16, 32), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(RNG, 3), (3, 3, 32, 64),
                          jnp.float32) * 0.1
    np.testing.assert_allclose(conv_op(x, w), conv_im2col_ref(x, w),
                               atol=1e-4, rtol=1e-4)
