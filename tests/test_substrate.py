"""Substrate: optimizer, compression, checkpoint, data pipeline, fault
tolerance, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip

from repro.ckpt import (CheckpointManager, latest_step, load_checkpoint,
                        save_checkpoint)
from repro.data import DataConfig, make_pipeline
from repro.optim import (AdafactorConfig, AdamWConfig, adafactor_init,
                         adafactor_update, adamw_init, adamw_update,
                         compress_int8, decompress_int8, ErrorFeedback)
from repro.runtime import FailureInjector, StragglerMonitor, run_with_restarts

RNG = jax.random.PRNGKey(0)


# -- optimizers ---------------------------------------------------------------

def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


def test_adamw_converges():
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(quad_loss)(params)
        params, state = adamw_update(params, g, state, cfg)
        state = {k: state[k] for k in ("mu", "nu", "count")}
    assert float(quad_loss(params)) < 1e-2


def test_adafactor_converges():
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    state = adafactor_init(params)
    cfg = AdafactorConfig(lr=0.3)
    for _ in range(300):
        g = jax.grad(quad_loss)(params)
        params, state = adafactor_update(params, g, state, cfg)
    assert float(quad_loss(params)) < 5e-2


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((64, 32))}
    state = adafactor_init(params)
    leaves = state["v"]["w"]
    assert leaves["vr"].shape == (64,)
    assert leaves["vc"].shape == (32,)


# -- compression --------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(1e-3, 1e3))
def test_int8_quant_error_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = compress_int8(x)
    deq = decompress_int8(q, s)
    amax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(deq - x))) <= amax / 127.0 + 1e-6


def test_error_feedback_beats_plain_quantization():
    """EF residual carry makes the accumulated (compressed) sum track the
    true sum more closely than memoryless quantization."""
    g = jax.random.normal(RNG, (256,)) * 0.01
    res = {"g": jnp.zeros((256,))}
    acc_ef = jnp.zeros((256,))
    acc_plain = jnp.zeros((256,))
    true = jnp.zeros((256,))
    for i in range(50):
        gi = g * (1 + 0.1 * i)
        true += gi
        out, res = ErrorFeedback.apply({"g": gi}, res)
        acc_ef += out["g"]
        q, s = compress_int8(gi)
        acc_plain += decompress_int8(q, s)
    assert float(jnp.linalg.norm(acc_ef - true)) <= \
        float(jnp.linalg.norm(acc_plain - true)) + 1e-5


# -- checkpointing ------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree)
        like = jax.tree.map(jnp.zeros_like, tree)
        got, step = load_checkpoint(d, like)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_gc_and_latest():
    tree = {"x": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, save_interval=1)
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, blocking=True)
        mgr.wait()
        kept = sorted(n for n in os.listdir(d) if n.startswith("step_"))
        assert kept == ["step_3", "step_4"]
        assert mgr.latest_step() == 4


def test_checkpoint_atomicity_tmp_ignored():
    tree = {"x": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        os.makedirs(os.path.join(d, "step_9.tmp"))   # simulated crash
        mgr = CheckpointManager(d)
        assert mgr.latest_step() == 1


def test_checkpoint_published_step_is_immutable_without_overwrite():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"x": jnp.zeros((2,))})
        # Silently clobbering a published step is the failure mode the
        # atomic layout exists to prevent: refuse by default...
        with pytest.raises(FileExistsError, match="step_3"):
            save_checkpoint(d, 3, {"x": jnp.ones((2,))})
        got, _ = load_checkpoint(d, {"x": jnp.zeros((2,))}, step=3)
        np.testing.assert_array_equal(np.asarray(got["x"]), [0.0, 0.0])
        # ...and replace only on the explicit opt-in.
        save_checkpoint(d, 3, {"x": jnp.ones((2,))}, overwrite=True)
        got, _ = load_checkpoint(d, {"x": jnp.zeros((2,))}, step=3)
        np.testing.assert_array_equal(np.asarray(got["x"]), [1.0, 1.0])
        # Managed saves replace in place (a restarted trainer re-saves the
        # step it restored) — no refusal through the manager.
        CheckpointManager(d, save_interval=1).save(3, {"x": jnp.zeros((2,))},
                                                   blocking=True)


def test_checkpoint_crash_mid_write_restores_previous_step():
    """A writer that dies mid-write leaves only a ``.tmp`` dir behind;
    restore never sees it, and the next save of that step reclaims it."""
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"x": jnp.full((2,), 5.0)})
        # Simulated crash mid-write of step 2: leaf written, no manifest,
        # never renamed.
        tmp = os.path.join(d, "step_2.tmp")
        os.makedirs(tmp)
        np.save(os.path.join(tmp, "0.npy"), np.ones((2,)))
        assert latest_step(d) == 1
        got, step = load_checkpoint(d, {"x": jnp.zeros((2,))})
        assert step == 1
        np.testing.assert_array_equal(np.asarray(got["x"]), [5.0, 5.0])
        # Retrying the crashed save is not an "overwrite" — the step was
        # never published — and it clears the leftovers.
        save_checkpoint(d, 2, {"x": jnp.full((2,), 7.0)})
        assert not os.path.exists(tmp)
        assert latest_step(d) == 2


# -- data pipeline -------------------------------------------------------------

def test_pipeline_host_sharding_disjoint_and_deterministic():
    def batches(host, n=2):
        cfg = DataConfig(global_batch=8, seq_len=16, vocab=100,
                         host_index=host, num_hosts=2, seed=5)
        p = make_pipeline(cfg)
        out = [next(iter(p)) for _ in range(n)]
        p.close()
        return out
    a0, a1 = batches(0), batches(1)
    b0 = batches(0)
    for x, y in zip(a0, b0):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])  # determinism
    assert not np.array_equal(a0[0]["tokens"], a1[0]["tokens"])  # disjoint
    assert a0[0]["tokens"].shape == (4, 16)                      # host slice


def test_pipeline_vision_weights_mask():
    cfg = DataConfig(global_batch=2, seq_len=16, vocab=100,
                     frontend_tokens=4, d_model=8)
    b = next(iter(make_pipeline(cfg)))
    assert b["frontend"].shape == (2, 4, 8)
    assert np.all(b["weights"][:, :4] == 0)      # patch positions unmasked
    assert np.all(b["weights"][:, 4:] == 1)


# -- fault tolerance -----------------------------------------------------------

def test_injector_and_supervisor():
    inj = FailureInjector([3])
    calls = []

    def attempt(n):
        calls.append(n)
        for s in range(1, 6):
            inj.check(s)
        return 5

    assert run_with_restarts(attempt, max_restarts=2) == 5
    assert calls == [0, 1]          # one restart


def test_supervisor_exhausts():
    inj = FailureInjector([1])

    def attempt(n):
        inj.fired.clear()           # keep failing
        inj.check(1)
        return 1

    with pytest.raises(RuntimeError):
        run_with_restarts(attempt, max_restarts=2)


def test_straggler_monitor_flags():
    import time
    mon = StragglerMonitor(alpha=0.5, threshold=1.5)
    for i in range(3):
        mon.start()
        time.sleep(0.01)
        mon.stop(i)
    mon.start()
    time.sleep(0.1)                  # 10× slower step
    assert mon.stop(99) is True
    assert 99 in mon.flagged
