"""Vectorized kl_refine vs the pure-Python reference: capacity safety,
pin immobility, and accepted-move quality (PR 3 satellite)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip

from repro.core.ilp import kl_refine, kl_refine_reference


def ring_pair_cost(ndev):
    return np.array([[min(abs(i - j), ndev - abs(i - j))
                      for j in range(ndev)] for i in range(ndev)],
                    dtype=float)


def objective(assign, edges, pair_cost):
    return sum(w * pair_cost[assign[u], assign[v]] for u, v, w in edges)


def random_instance(data, min_nodes=4, max_nodes=40):
    ndev = data.draw(st.integers(2, 6))
    nv = data.draw(st.integers(min_nodes, max_nodes))
    nodes = [f"n{i}" for i in range(nv)]
    assign = {n: data.draw(st.integers(0, ndev - 1)) for n in nodes}
    ne = data.draw(st.integers(0, nv * 3))
    edges = [(nodes[data.draw(st.integers(0, nv - 1))],
              nodes[data.draw(st.integers(0, nv - 1))],
              float(data.draw(st.integers(1, 128))))
             for _ in range(ne)]
    nk = data.draw(st.integers(1, 3))
    area = {n: np.array([data.draw(st.floats(0.5, 8.0))
                         for _ in range(nk)]) for n in nodes}
    # Loose enough that refinement has room, tight enough to bind sometimes.
    caps = np.full((ndev, nk), float(nv * 8 // ndev + 10))
    return assign, edges, ring_pair_cost(ndev), area, caps, ndev, nk


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_capacity_never_violated(data):
    assign, edges, pc, area, caps, ndev, nk = random_instance(data)
    out = kl_refine(assign, edges, pc, area, caps)
    usage = np.zeros((ndev, nk))
    for v, d in out.items():
        usage[d] += area[v]
    assert np.all(usage <= caps + 1e-6)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_pinned_tasks_never_moved(data):
    assign, edges, pc, area, caps, ndev, nk = random_instance(data)
    nodes = list(assign)
    pinned = nodes[::3]
    out = kl_refine(assign, edges, pc, area, caps, pinned=pinned)
    for n in pinned:
        assert out[n] == assign[n]


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_vectorized_no_worse_than_reference(data):
    assign, edges, pc, area, caps, ndev, nk = random_instance(data)
    ref = kl_refine_reference(assign, edges, pc, area, caps)
    vec = kl_refine(assign, edges, pc, area, caps)
    assert (objective(vec, edges, pc)
            <= objective(ref, edges, pc) + 1e-6)


def test_identical_decisions_on_integer_costs():
    """On integer-valued widths/distances the two refiners make the exact
    same greedy move sequence, not just equal-quality ones."""
    rng = np.random.default_rng(3)
    ndev, nv = 5, 64
    nodes = [f"n{i}" for i in range(nv)]
    assign = {n: int(rng.integers(0, ndev)) for n in nodes}
    edges = [(nodes[int(rng.integers(nv))], nodes[int(rng.integers(nv))],
              float(rng.integers(1, 64))) for _ in range(nv * 3)]
    area = {n: rng.integers(1, 6, 2).astype(float) for n in nodes}
    caps = np.full((ndev, 2), float(nv * 6 // ndev + 8))
    pc = ring_pair_cost(ndev)
    assert (kl_refine(assign, edges, pc, area, caps)
            == kl_refine_reference(assign, edges, pc, area, caps))


def test_self_loops_and_empty_inputs():
    pc = ring_pair_cost(3)
    area = {"a": np.array([1.0]), "b": np.array([1.0])}
    caps = np.full((3, 1), 10.0)
    # Self-loop edges are ignored (cost is device-local either way).
    out = kl_refine({"a": 0, "b": 2}, [("a", "a", 9.0), ("a", "b", 4.0)],
                    pc, area, caps)
    assert objective(out, [("a", "b", 4.0)], pc) == 0.0
    assert kl_refine({}, [], pc, {}, caps) == {}
