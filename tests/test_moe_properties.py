"""MoE dispatch/combine invariants (hypothesis property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip

from repro.models.moe import MoEConfig, init_moe, moe_forward, _route


def mk(E=8, k=2, D=16, F=32, cf=2.0, shared=0, aux_free=True):
    return MoEConfig(d_model=D, d_ff_expert=F, num_experts=E, top_k=k,
                     num_shared=shared, capacity_factor=cf,
                     aux_loss_free=aux_free)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 3), st.integers(2, 8),
       st.booleans())
def test_moe_output_finite_and_shaped(seed, G, S, aux_free):
    cfg = mk(aux_free=aux_free)
    params = init_moe(jax.random.PRNGKey(seed % 100), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (G, S, cfg.d_model))
    y, aux = moe_forward(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 0.0


def test_moe_combine_weights_normalized():
    cfg = mk()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    idx, w, _ = _route(params, cfg, x)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert bool(jnp.all(idx >= 0)) and bool(jnp.all(idx < cfg.num_experts))


def test_moe_capacity_drops_zero_not_garbage():
    """With capacity_factor → 0, every token is dropped: routed output must
    be exactly zero (shared expert disabled), never stale/garbage."""
    cfg = mk(cf=1e-9, shared=0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_forward(params, cfg, x)
    # capacity C = max(1, 0) = 1 → at most E tokens survive per group; the
    # rest contribute 0. Check: outputs for tokens routed past capacity are
    # exactly 0 rows.
    zero_rows = int(jnp.sum(jnp.all(y == 0.0, axis=-1)))
    assert zero_rows >= 2 * 16 - 2 * cfg.num_experts


def test_moe_permutation_equivariance():
    """Permuting tokens within a group permutes outputs identically (as
    long as no drops occur: generous capacity)."""
    cfg = mk(cf=8.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    perm = jnp.array([3, 1, 7, 0, 5, 2, 6, 4])
    y1, _ = moe_forward(params, cfg, x)
    y2, _ = moe_forward(params, cfg, x[:, perm, :])
    np.testing.assert_allclose(np.asarray(y1[:, perm, :]), np.asarray(y2),
                               atol=2e-5)


def test_aux_free_bias_changes_routing_not_weights():
    """DeepSeek aux-free bias shifts SELECTION but combine weights stay
    softmax(logits) — bias must not leak into the mixture weights."""
    cfg = mk(aux_free=True)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, cfg.d_model))
    idx0, w0, _ = _route(params, cfg, x)
    biased = dict(params)
    biased["router_bias_e"] = params["router_bias_e"].at[0].add(100.0)
    idx1, w1, _ = _route(biased, cfg, x)
    assert bool(jnp.all(idx1[..., 0] == 0))          # expert 0 always picked
    # weight of expert 0 is its softmax prob, NOT ~1.0 from the bias
    probs = jax.nn.softmax(
        jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                   params["router_de"]), -1)
    np.testing.assert_allclose(np.asarray(w1[..., 0]),
                               np.asarray(jnp.take_along_axis(
                                   probs, idx1[..., :1], -1)[..., 0]
                                   / jnp.sum(jnp.take_along_axis(
                                       probs, idx1, -1), -1)),
                               atol=1e-5)
