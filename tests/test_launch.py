"""Launch layer: sharding rules, plans, graphs, analytic accounting, and a
small-mesh dry-run in a subprocess (8 fake host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_arch, input_specs
from repro.launch.analytic import active_param_count, analyze
from repro.launch.graphs import build_lm_graph, total_param_bytes
from repro.launch.plan import make_plan
from repro.models import init_params


def test_total_param_bytes_matches_eval_shape():
    """Analytic param accounting vs the real init tree (hard consistency)."""
    for arch in ("qwen3-4b", "gemma2-27b", "deepseek-v2-236b", "xlstm-1.3b",
                 "recurrentgemma-9b"):
        cfg = get_arch(arch).full()
        shapes = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c))
        true_bytes = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(shapes))
        est = total_param_bytes(cfg)
        # Analytic skips norm scales/router bias (tiny) — within 3%.
        assert abs(est - true_bytes) / true_bytes < 0.03, \
            (arch, est / 1e9, true_bytes / 1e9)


def test_lm_graph_structure():
    cfg = get_arch("qwen3-4b").full()
    g = build_lm_graph(cfg, 256, 4096)
    assert len(g.tasks) == 36 + 2          # layers + embed + head
    g.validate()
    # chain topology with single path
    assert len(g.channels) == 37


def test_encdec_graph_has_reconvergent_edges():
    cfg = get_arch("seamless-m4t-large-v2").full()
    g = build_lm_graph(cfg, 256, 4096)
    enc_out = g.out_channels("encoder")
    assert len(enc_out) == 24              # fan-out to every decoder layer


def test_plan_optimizer_gates():
    assert make_plan("qwen3-4b", get_arch("qwen3-4b").full(),
                     "train_4k").optimizer == "adamw"
    assert make_plan("deepseek-v3-671b", get_arch("deepseek-v3-671b").full(),
                     "train_4k").optimizer == "adafactor"
    assert make_plan("deepseek-v2-236b", get_arch("deepseek-v2-236b").full(),
                     "train_4k").optimizer == "adafactor"


def test_plan_multi_pod_partitions():
    cfg = get_arch("gemma2-27b").full()
    p = make_plan("gemma2-27b", cfg, "train_4k", num_pods=2)
    assert p.partition is not None
    assert p.partition.num_devices() == 2
    assert p.pipeline_depths is not None


def test_analytic_flops_scale():
    """6·N·D sanity: train FLOPs within 2× band of 6·N_active·tokens."""
    for arch in ("qwen3-4b", "mistral-nemo-12b", "deepseek-v2-236b"):
        cfg = get_arch(arch).full()
        cell = analyze(cfg, "train_4k")
        ratio = cell.model_flops / cell.flops_global
        assert 0.3 < ratio <= 1.0, (arch, ratio)


def test_input_specs_cover_all_cells():
    from repro.configs import SHAPES, supported_shapes
    for arch in ALL_ARCHS:
        mod = get_arch(arch)
        cfg = mod.full()
        for shape in supported_shapes(mod):
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if SHAPES[shape].kind == "decode":
                assert "cache" in specs and "pos" in specs


SUBPROC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, json
    import jax.numpy as jnp
    from repro.configs import get_arch, input_specs
    from repro.launch import hlo_analysis, steps
    from repro.launch.mesh import make_mesh

    import dataclasses
    cfg = dataclasses.replace(get_arch("qwen3-4b").smoke(),
                              dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)
    out = {}
    for name, mesh in (("single", make_mesh((2, 4), ("data", "model"))),
                       ("multi", make_mesh((2, 2, 2),
                                           ("pod", "data", "model")))):
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "targets": jax.ShapeDtypeStruct((8, 16), jnp.int32),
            "weights": jax.ShapeDtypeStruct((8, 16), jnp.float32),
        }
        lowered = steps.lower_train(cfg, mesh, batch, microbatches=2)
        compiled = lowered.compile()
        # cost_summary normalizes the jax 0.4.3x one-element-list return of
        # compiled.cost_analysis() (a raw .get() here broke on that version).
        ca = hlo_analysis.cost_summary(compiled)
        out[name] = {"flops": ca["flops"], "ok": True}
    print(json.dumps(out))
""")


def test_dryrun_small_mesh_subprocess():
    """lower+compile on 8 fake devices, single- and multi-pod meshes.
    Run in a subprocess: device count locks at first jax init."""
    env = dict(os.environ)
    # Hermetic w.r.t. the caller's environment: the script needs src/ on the
    # path (prepended so an ambient PYTHONPATH can't shadow the repo) and
    # must own XLA_FLAGS (the device count locks at first jax init).
    ambient = env.get("PYTHONPATH")
    env["PYTHONPATH"] = "src" + (os.pathsep + ambient if ambient else "")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["single"]["ok"] and out["multi"]["ok"]
    assert out["single"]["flops"] > 0
