"""Import hypothesis when available; otherwise supply stand-ins so the
suite still *collects* and the plain unit tests in the same modules run.

Without this, a missing ``hypothesis`` (it is a dev-only dependency — see
requirements-dev.txt) aborted collection of every module that imported it.
With the stand-ins, ``@given``-decorated property tests report SKIPPED and
everything else runs normally.
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategies.<name>(...) call, returns a placeholder."""

        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        # Return a zero-arg replacement (mirroring hypothesis' own wrapper)
        # so pytest doesn't try to resolve the strategy params as fixtures.
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
