"""Golden equivalence of the PR 3 fast path vs the legacy solver path.

The vectorized COO model build + halved linearization + vectorized KL must
produce the same Eq. 2 partition objective as the legacy dict-row build +
pure-Python KL (``use_reference=True``) on the paper app graphs — the same
cross-check ``benchmarks/perf.py`` runs on the full config matrix."""
import numpy as np
import pytest

from repro.apps import cnn, knn, pagerank, stencil
from repro.core import fpga_ring_cluster
from repro.core.ilp import ILPError, Model
from repro.core.partitioner import partition


@pytest.mark.parametrize("mod,ndev", [
    (stencil, 2), (stencil, 4),
    (pagerank, 2), (pagerank, 4),
    (cnn, 2),
    (knn, 2),
], ids=lambda p: getattr(p, "__name__", str(p)).split(".")[-1])
def test_partition_objective_matches_legacy(mod, ndev):
    cl = fpga_ring_cluster(ndev)
    p_new = partition(mod.build_graph(ndev), cl,
                      balance_kind="LUT", balance_tol=0.8)
    p_ref = partition(mod.build_graph(ndev), cl,
                      balance_kind="LUT", balance_tol=0.8,
                      use_reference=True)
    assert p_new.comm_cost == pytest.approx(p_ref.comm_cost, rel=1e-6)
    # The drift invariant holds and Eq. 1 holds on the fast path.
    assert p_new.stats.objective == p_new.comm_cost
    caps = np.array([[cl.capacity(k) for k in p_new.kinds]
                     for _ in range(ndev)])
    assert np.all(p_new.usage <= caps + 1e-6)
    assert set(p_new.assignment) == set(p_ref.assignment)


def test_unpinned_kl_polish_matches_legacy_without_balance():
    """No balance band → the KL polish actually runs in both paths."""
    cl = fpga_ring_cluster(4)
    g_new, g_ref = stencil.build_graph(4), stencil.build_graph(4)
    p_new = partition(g_new, cl)
    p_ref = partition(g_ref, cl, use_reference=True)
    assert p_new.comm_cost == pytest.approx(p_ref.comm_cost, rel=1e-6)


def test_time_limit_degrades_to_feasible_instead_of_raising():
    """A branch-and-cut time limit too small to prove optimality now falls
    back to the HiGHS incumbent or the KL warm start (PR 3); the seed
    behaviour was an ILPError."""
    g = knn.build_graph(4)
    cl = fpga_ring_cluster(4)
    p = partition(g, cl, balance_kind="LUT", balance_tol=0.8,
                  time_limit=1e-3)
    assert set(p.assignment) == set(g.task_names())
    kinds = p.kinds
    caps = np.array([[cl.capacity(k) for k in kinds] for _ in range(4)])
    assert np.all(p.usage <= caps + 1e-6)
    assert p.stats.method.startswith("milp-exact")


def test_bulk_row_apis_match_dict_api():
    """Same tiny ILP emitted via dict rows and via the bulk COO APIs must
    produce identical solutions."""

    def build(bulk: bool) -> Model:
        m = Model("t")
        if bulk:
            x = m.add_vars(4, 0.0, 1.0, integer=True,
                           obj=np.array([1.0, 2.0, 3.0, 4.0]))
            cols = np.arange(x, x + 4)
            m.add_eq_rows(cols[None, :], np.ones((1, 4)), 2.0)
            m.add_ge_rows(np.array([[0, 1], [2, 3]]),
                          np.ones((2, 2)), 1.0)
            m.add_le_rows(np.array([[0, 3]]), np.ones((1, 2)), 1.0)
        else:
            x = [m.add_binary(obj=c) for c in (1.0, 2.0, 3.0, 4.0)]
            m.add_eq({v: 1.0 for v in x}, 2.0)
            m.add_ge({x[0]: 1.0, x[1]: 1.0}, 1.0)
            m.add_ge({x[2]: 1.0, x[3]: 1.0}, 1.0)
            m.add_le({x[0]: 1.0, x[3]: 1.0}, 1.0)
        return m

    s_dict = build(bulk=False).solve()
    s_bulk = build(bulk=True).solve()
    assert np.allclose(s_dict, s_bulk)
    assert np.allclose(s_bulk, [1.0, 0.0, 1.0, 0.0])


def test_warm_start_fallback_is_validated():
    """solve() only returns a warm start that actually satisfies the model;
    an infeasible model with a bogus warm start still raises."""
    m = Model("infeasible")
    v = m.add_binary()
    m.add_ge({v: 1.0}, 2.0)          # impossible for a binary
    with pytest.raises(ILPError):
        m.solve(warm_start=np.array([1.0]))
