"""End-to-end system behaviour: the full TAPA-CS pipeline on a real model
graph, train-to-convergence on a tiny task, checkpoint-restart equivalence,
and serving consistency."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import (ALVEO_U55C, fpga_ring_cluster, simulate,
                        tpu_pod_cluster, verify_balanced)
# Raw implementations: the repro.core package-level names are deprecation
# shims (use repro.compiler.compile in new code).
from repro.core.partitioner import partition
from repro.core.pipelining import pipeline_interconnect
from repro.launch.graphs import build_lm_graph
from repro.models import init_params, train_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update


def test_full_tapa_cs_pipeline_on_lm_graph():
    """graph -> partition -> pipeline -> simulate on qwen3 over 2 TPU pods."""
    cfg = get_arch("qwen3-4b").full()
    g = build_lm_graph(cfg, 256, 4096, state_mult=6.0)
    for t in g.tasks.values():
        t.area = type(t.area)({"hbm_bytes": t.area["hbm_bytes"] / 1e9,
                               "flops": t.area["flops"] / 1e12})
    cl = tpu_pod_cluster(2)
    tot = sum(t.area["flops"] for t in g.tasks.values())
    cl.device.resources["hbm_bytes"] = 16 * 256
    cl.device.resources["flops"] = 2 * tot
    p = partition(g, cl, balance_kind="flops", balance_tol=0.5,
                  exact_limit=2000)
    assert p.num_devices() == 2
    rep = pipeline_interconnect(g, p, cluster=cl)
    assert verify_balanced(g, rep)
    res = simulate(g, p, cl, {0: 1.0, 1: 1.0})
    assert res.makespan > 0


def test_training_reduces_loss():
    cfg = get_arch("qwen3-4b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    ocfg = AdamWConfig(lr=3e-3)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1),
             "weights": jnp.ones_like(toks, jnp.float32)}

    @jax.jit
    def step(params, opt):
        loss, g = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch))(params)
        params, new = adamw_update(params, g, opt, ocfg)
        return params, {k: new[k] for k in ("mu", "nu", "count")}, loss

    losses = []
    for _ in range(30):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert losses[-1] < 0.5 * losses[0], losses[::10]


def test_checkpoint_restart_bitexact():
    """Training N steps straight == training with a mid save/restore."""
    from repro.ckpt import load_checkpoint, save_checkpoint
    cfg = get_arch("chatglm3-6b").smoke()
    ocfg = AdamWConfig(lr=1e-3)
    data = jax.random.randint(jax.random.PRNGKey(2), (6, 2, 16), 0,
                              cfg.vocab)

    @jax.jit
    def step(state, toks):
        batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1),
                 "weights": jnp.ones_like(toks, jnp.float32)}
        loss, g = jax.value_and_grad(
            lambda p: train_loss(p, cfg, batch))(state["params"])
        params, new = adamw_update(state["params"], g, state["opt"], ocfg)
        return {"params": params,
                "opt": {k: new[k] for k in ("mu", "nu", "count")}}, loss

    def init():
        p = init_params(jax.random.PRNGKey(0), cfg)
        return {"params": p, "opt": adamw_init(p)}

    s = init()
    for i in range(6):
        s, _ = step(s, data[i])
    straight = s

    s = init()
    for i in range(3):
        s, _ = step(s, data[i])
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, s)
        s, _ = load_checkpoint(d, jax.tree.map(jnp.zeros_like, s))
    for i in range(3, 6):
        s, _ = step(s, data[i])

    for a, b in zip(jax.tree.leaves(straight["params"]),
                    jax.tree.leaves(s["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_serving_matches_manual_decode():
    from repro.models import init_cache, serve_step
    from repro.serving import ServeConfig, ServingEngine
    cfg = get_arch("qwen3-4b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.array([[5, 6, 7], [8, 9, 10]], np.int32)
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=2, max_len=32))
    out = eng.generate(prompts, max_new=5)

    cache = init_cache(cfg, 2, 32)
    logits = None
    for t in range(3):
        cache, logits = serve_step(params, cfg, cache,
                                   jnp.asarray(prompts[:, t:t + 1]),
                                   jnp.int32(t))
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(5):
        toks.append(np.asarray(tok))
        cache, logits = serve_step(params, cfg, cache, tok[:, None],
                                   jnp.int32(3 + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    np.testing.assert_array_equal(out, np.stack(toks, 1))
