"""Topology dist() properties (paper Eq. 3 + variants) — unit + property."""
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip

from repro.core import (Bus, DaisyChain, Hypercube, Mesh2D, Ring, Star,
                        lam, ETHERNET_100G, PCIE_GEN3X16, TPU_DCN, TPU_ICI)

TOPOS = [
    lambda n: DaisyChain(n),
    lambda n: Ring(n),
    lambda n: Bus(n),
    lambda n: Star(n),
    lambda n: Mesh2D(2, (n + 1) // 2),
    lambda n: Hypercube(max(1, (n - 1).bit_length())),
]


def test_daisy_chain_matches_eq3():
    t = DaisyChain(4)
    assert t.dist(0, 3) == 3
    assert t.dist(2, 1) == 1


def test_ring_matches_paper():
    t = Ring(4)                      # paper testbed: 4-FPGA ring
    assert t.dist(0, 3) == 1         # wraps
    assert t.dist(0, 2) == 2
    assert t.diameter() == 2


def test_lambda_scaling_pcie():
    # §4.3: PCIe Gen3x16 cost scaled 12.5× vs Ethernet.
    assert lam(PCIE_GEN3X16) == pytest.approx(12.5)
    assert lam(ETHERNET_100G) == pytest.approx(1.0)
    assert lam(TPU_DCN, TPU_ICI) == pytest.approx(8.0)


def test_hypercube():
    t = Hypercube(3)
    assert t.dist(0b000, 0b111) == 3
    assert t.diameter() == 3


def test_mesh_torus_wrap():
    m = Mesh2D(4, 4, torus=True)
    assert m.dist(0, 3) == 1         # column wrap
    assert m.dist(0, 12) == 1        # row wrap


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 5), st.integers(2, 10), st.integers(0, 100),
       st.integers(0, 100))
def test_metric_properties(kind, n, a, b):
    topo = TOPOS[kind](n)
    i, j = a % topo.num_devices, b % topo.num_devices
    assert topo.dist(i, i) == 0
    assert topo.dist(i, j) == topo.dist(j, i)        # symmetry
    assert topo.dist(i, j) >= 0


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(0, 200), st.integers(0, 200),
       st.integers(0, 200))
def test_ring_triangle_inequality(n, a, b, c):
    t = Ring(n)
    i, j, k = a % n, b % n, c % n
    assert t.dist(i, k) <= t.dist(i, j) + t.dist(j, k)


# ---------------------------------------------------------------------------
# links() / neighbors() defaults + fabric-backed diameter().
# ---------------------------------------------------------------------------

def test_ring_links_and_neighbors():
    t = Ring(4)
    assert sorted(t.neighbors(0)) == [1, 3]          # wraps
    assert t.links() == [(0, 1), (0, 3), (1, 2), (2, 3)]


def test_daisy_chain_links():
    t = DaisyChain(4)
    assert t.links() == [(0, 1), (1, 2), (2, 3)]
    assert t.neighbors(0) == [1] and sorted(t.neighbors(2)) == [1, 3]


def test_torus_wraparound_neighbors():
    m = Mesh2D(3, 3, torus=True)
    # Corner 0 = (0,0): grid neighbors (0,1),(1,0) + wraps (0,2),(2,0).
    assert sorted(m.neighbors(0)) == [1, 2, 3, 6]
    assert len(m.links()) == 2 * 9                   # 2 cables per torus node
    flat = Mesh2D(3, 3)
    assert sorted(flat.neighbors(0)) == [1, 3]       # no wraparound


def test_hypercube_bit_flip_neighbors():
    h = Hypercube(3)
    assert sorted(h.neighbors(0b000)) == [0b001, 0b010, 0b100]
    assert sorted(h.neighbors(0b101)) == [0b001, 0b100, 0b111]
    assert len(h.links()) == 3 * 8 // 2              # dim × n / 2 cables


def test_star_hub_links():
    s = Star(5)
    assert s.links() == [(0, 1), (0, 2), (0, 3), (0, 4)]
    assert s.neighbors(3) == [0]                     # spokes see only the hub
    assert sorted(s.neighbors(0)) == [1, 2, 3, 4]


def test_bus_is_shared_medium():
    b = Bus(4)
    assert b.shared_medium
    assert sorted(b.neighbors(2)) == [0, 1, 3]       # every pair one hop


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 5), st.integers(2, 10))
def test_diameter_matches_exhaustive_dist_scan(kind, n):
    """The fabric-sweep diameter() equals the O(n²) dist() definition."""
    topo = TOPOS[kind](n)
    m = topo.num_devices
    exhaustive = max(topo.dist(i, j) for i in range(m) for j in range(m))
    assert topo.diameter() == exhaustive
    assert topo.diameter() == exhaustive             # memoized second call


def test_diameter_falls_back_for_unrealizable_metrics():
    class Teleport(Ring):
        """dist()==2 everywhere: no dist()==1 links exist to route over."""
        def dist(self, i, j):
            return 0 if i == j else 2

    assert Teleport(5).diameter() == 2
