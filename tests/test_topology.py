"""Topology dist() properties (paper Eq. 3 + variants) — unit + property."""
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip

from repro.core import (Bus, DaisyChain, Hypercube, Mesh2D, Ring, Star,
                        lam, ETHERNET_100G, PCIE_GEN3X16, TPU_DCN, TPU_ICI)

TOPOS = [
    lambda n: DaisyChain(n),
    lambda n: Ring(n),
    lambda n: Bus(n),
    lambda n: Star(n),
    lambda n: Mesh2D(2, (n + 1) // 2),
    lambda n: Hypercube(max(1, (n - 1).bit_length())),
]


def test_daisy_chain_matches_eq3():
    t = DaisyChain(4)
    assert t.dist(0, 3) == 3
    assert t.dist(2, 1) == 1


def test_ring_matches_paper():
    t = Ring(4)                      # paper testbed: 4-FPGA ring
    assert t.dist(0, 3) == 1         # wraps
    assert t.dist(0, 2) == 2
    assert t.diameter() == 2


def test_lambda_scaling_pcie():
    # §4.3: PCIe Gen3x16 cost scaled 12.5× vs Ethernet.
    assert lam(PCIE_GEN3X16) == pytest.approx(12.5)
    assert lam(ETHERNET_100G) == pytest.approx(1.0)
    assert lam(TPU_DCN, TPU_ICI) == pytest.approx(8.0)


def test_hypercube():
    t = Hypercube(3)
    assert t.dist(0b000, 0b111) == 3
    assert t.diameter() == 3


def test_mesh_torus_wrap():
    m = Mesh2D(4, 4, torus=True)
    assert m.dist(0, 3) == 1         # column wrap
    assert m.dist(0, 12) == 1        # row wrap


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 5), st.integers(2, 10), st.integers(0, 100),
       st.integers(0, 100))
def test_metric_properties(kind, n, a, b):
    topo = TOPOS[kind](n)
    i, j = a % topo.num_devices, b % topo.num_devices
    assert topo.dist(i, i) == 0
    assert topo.dist(i, j) == topo.dist(j, i)        # symmetry
    assert topo.dist(i, j) >= 0


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(0, 200), st.integers(0, 200),
       st.integers(0, 200))
def test_ring_triangle_inequality(n, a, b, c):
    t = Ring(n)
    i, j, k = a % n, b % n, c % n
    assert t.dist(i, k) <= t.dist(i, j) + t.dist(j, k)
