"""Intra-device floorplanning (Eq. 4) + interconnect pipelining (C5)."""
import numpy as np
import pytest

from repro.core import (ALVEO_U55C, SlotGrid, U55C_GRID, fpga_ring_cluster,
                        linear_graph, verify_balanced,
                        ResourceProfile, Task, TaskGraph)
# Raw implementations: the repro.core package-level names are deprecation
# shims (use repro.compiler.compile in new code).
from repro.core.floorplan import floorplan_device
from repro.core.partitioner import partition
from repro.core.pipelining import pipeline_interconnect


def test_floorplan_slot_capacity():
    g = linear_graph(6, width_bits=128, area={"LUT": 50000, "DSP": 100})
    fp = floorplan_device(g, g.task_names(), ALVEO_U55C.resources)
    assert fp.grid.num_slots == 6
    caps = ALVEO_U55C.resources["LUT"] / 6 * 0.70
    for s in range(6):
        assert fp.usage[s, fp.kinds.index("LUT")] <= caps + 1e-6


def test_floorplan_chain_adjacent():
    g = linear_graph(4, width_bits=512, area={"LUT": 150000})
    fp = floorplan_device(g, g.task_names(), ALVEO_U55C.resources)
    # Chain should占 adjacent slots: wirelength = 3 hops × 512.
    assert fp.wirelength <= 3 * 512


def test_hbm_task_binding():
    """HBM-reading tasks prefer HBM-adjacent rows (§4.5 channel binding)."""
    g = TaskGraph("hbm")
    for i in range(4):
        g.add_task(Task(f"t{i}", ResourceProfile({"LUT": 1000.0})))
    g.add_channel("t0", "t1", 64)
    g.add_channel("t1", "t2", 64)
    g.add_channel("t2", "t3", 64)
    fp = floorplan_device(g, g.task_names(), ALVEO_U55C.resources,
                          hbm_tasks=["t0"])
    row0_slots = {fp.grid.slot_id(0, c) for c in range(fp.grid.cols)}
    assert fp.slot_of["t0"] in row0_slots


def test_pipeline_balancing_reconvergent():
    """Fork/join with unequal paths must be buffered equal (cut-set rule)."""
    g = TaskGraph("fork")
    for n in ("src", "a", "b1", "b2", "join"):
        g.add_task(Task(n, ResourceProfile({"LUT": 10.0})))
    g.add_channel("src", "a", 64)          # short path: src→a→join
    g.add_channel("a", "join", 64)
    g.add_channel("src", "b1", 64)         # long path: src→b1→b2→join
    g.add_channel("b1", "b2", 64)
    g.add_channel("b2", "join", 64)
    cl = fpga_ring_cluster(2)
    p = partition(g, cl)
    rep = pipeline_interconnect(g, p, cluster=cl)
    assert verify_balanced(g, rep)
    assert all(d >= 2 for d in rep.depth.values())


def test_crossing_depth_scales_with_distance():
    g = linear_graph(4, width_bits=64, area={"LUT": 10.0})
    cl = fpga_ring_cluster(4)
    p = partition(g, cl, balance_kind="LUT", balance_tol=0.1)
    rep = pipeline_interconnect(g, p, cluster=cl)
    # cross-device channels carry at least dist+1 register stages
    for i, c in enumerate(g.channels):
        d1, d2 = p.assignment[c.src], p.assignment[c.dst]
        if d1 != d2:
            assert rep.added_latency[i] >= cl.topology.dist(d1, d2) + 1
