"""Cross-check analytic FLOP accounting against XLA cost_analysis via
layer-count differencing (unrolled configs so while-undercounting cannot
bias the check) — DESIGN.md §6."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.launch.analytic import prefill_flops
from repro.launch.graphs import layer_flops
from repro.launch.hlo_analysis import cost_summary
from repro.models import LayerSpec, init_params
from repro.models import transformer as T
from repro.models import layers


def _forward_flops(cfg, batch, seq):
    """cost_analysis FLOPs of the full forward (logits of last position)."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)

    def fwd(p, t):
        x = T._embed_inputs(p, cfg, {"tokens": t})
        pos = jnp.broadcast_to(jnp.arange(seq), (batch, seq))
        x, _ = T._run_stack(p, cfg, x, pos)
        x = layers.rmsnorm(p["final_norm"], x)
        return layers.unembed(T._unembed_table(p, cfg), x[:, -1, :])

    compiled = jax.jit(fwd).lower(
        jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg)),
        toks).compile()
    # cost_summary normalizes the jax 0.4.3x one-element-list return shape.
    return cost_summary(compiled)["flops"]


def test_layer_flops_matches_hlo_differencing():
    base = get_arch("qwen3-4b").smoke()
    B, S = 2, 64
    # Unrolled stacks: pattern×L with ONE superblock → no while loop.
    cfg1 = dataclasses.replace(base, pattern=(LayerSpec("gqa", "dense"),),
                               num_superblocks=1, q_chunk=S)
    cfg3 = dataclasses.replace(base,
                               pattern=(LayerSpec("gqa", "dense"),) * 3,
                               num_superblocks=1, q_chunk=S)
    f1 = _forward_flops(cfg1, B, S)
    f3 = _forward_flops(cfg3, B, S)
    hlo_per_layer = (f3 - f1) / 2.0
    analytic = layer_flops(cfg1, LayerSpec("gqa", "dense"), B, S)
    # within 25% (HLO counts softmax/norm flops the analytic model rounds)
    assert abs(hlo_per_layer - analytic) / analytic < 0.25, \
        (hlo_per_layer, analytic)


def test_prefill_flops_scale_with_seq():
    cfg = get_arch("qwen3-4b").full()
    f4k = prefill_flops(cfg, 1, 4096)
    f8k = prefill_flops(cfg, 1, 8192)
    # Between 2× (pure linear) and 4× (pure quadratic).
    assert 2.0 < f8k / f4k < 4.0
