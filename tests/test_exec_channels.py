"""Unit tests for the executor's bounded FIFO channels (repro.exec)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Channel
from repro.exec import FifoChannel, token_bytes


def _ch(depth=2, latency=1, src_dev=0, dst_dev=0, width=512):
    gch = Channel("a", "b", width, bytes_per_step=64.0)
    gch.depth = depth
    return FifoChannel(0, gch, src_dev, dst_dev, latency=latency)


def test_capacity_bounds_pushes():
    ch = _ch(depth=2)
    ch.push(jnp.zeros(4), sweep=0)
    ch.push(jnp.zeros(4), sweep=0)
    assert ch.full
    with pytest.raises(RuntimeError, match="full"):
        ch.push(jnp.zeros(4), sweep=0)
    assert ch.stats.blocked_pushes == 1
    assert ch.stats.max_occupancy == 2


def test_latency_gates_visibility():
    ch = _ch(depth=4, latency=3)
    ch.push(jnp.arange(4.0), sweep=0)
    for sweep in (0, 1, 2):
        assert not ch.head_visible(sweep)
    assert ch.head_visible(3)
    out = ch.pop(3)
    np.testing.assert_array_equal(np.asarray(out), np.arange(4.0))


def test_pop_unripe_raises():
    ch = _ch(depth=2, latency=2)
    ch.push(jnp.zeros(2), sweep=5)
    with pytest.raises(RuntimeError, match="empty/unripe"):
        ch.pop(5)
    assert ch.stats.empty_pops == 1


def test_fifo_order_preserved():
    ch = _ch(depth=3)
    for i in range(3):
        ch.push(jnp.full((2,), float(i)), sweep=i)
    got = [float(ch.pop(10)[0]) for _ in range(3)]
    assert got == [0.0, 1.0, 2.0]


def test_inter_device_measures_bytes():
    ch = _ch(depth=2, src_dev=0, dst_dev=1)
    assert ch.inter_device and ch.eager_transfer
    tok = {"x": jnp.zeros((4, 4), jnp.float32), "y": jnp.zeros(2)}
    ch.push(tok, sweep=0)
    assert ch.stats.measured_bytes == token_bytes(tok) == 4 * 4 * 4 + 2 * 4


def test_intra_device_measures_nothing():
    ch = _ch(depth=2, src_dev=1, dst_dev=1)
    assert not ch.inter_device
    ch.push(jnp.zeros((8, 8)), sweep=0)
    assert ch.stats.measured_bytes == 0


def test_depth1_disables_double_buffering():
    """§4.6: a depth-1 inter-device FIFO cannot overlap its transfer."""
    assert _ch(depth=2, dst_dev=1).eager_transfer
    assert not _ch(depth=1, dst_dev=1).eager_transfer
    assert not _ch(depth=4).eager_transfer          # intra-device: no move


def test_prime_deposits_visible_token():
    ch = _ch(depth=2, latency=4)
    ch.prime(jnp.ones(3))
    assert ch.head_visible(0)       # primed tokens are visible at once
    np.testing.assert_array_equal(np.asarray(ch.pop(0)), np.ones(3))


def test_capacity_validation():
    gch = Channel("a", "b", 512)
    gch.depth = 0
    with pytest.raises(ValueError, match="capacity"):
        FifoChannel(0, gch, 0, 0)
    gch.depth = 2
    with pytest.raises(ValueError, match="latency"):
        FifoChannel(0, gch, 0, 0, latency=0)


# ---------------------------------------------------------------------------
# Double-buffered (depth >= 2) transfers over a contended fabric link.
# ---------------------------------------------------------------------------

def _fabric_pair(depth=4, mtu=64, budget_flits=1, credits=4):
    """Two depth>=2 channels whose routes share the single 0->1 link."""
    from repro.core.topology import DaisyChain, ETHERNET_100G
    from repro.net import FabricTransport, NetConfig, build_fabric

    fab = build_fabric(DaisyChain(2))
    cfg = NetConfig(mtu_bytes=mtu, link_credits=credits,
                    sweep_time_s=(budget_flits * mtu)
                    / ETHERNET_100G.bandwidth_Bps)
    tr = FabricTransport(fab, cfg)
    chans = []
    for i in range(2):
        gch = Channel("a", "b", 512, bytes_per_step=64.0)
        gch.depth = depth
        chans.append(FifoChannel(i, gch, 0, 1, transport=tr))
    return tr, chans


def test_fabric_delivery_gates_visibility():
    """With a transport, a push is visible only after its message's final
    flit delivers — not at the fixed push+latency sweep."""
    tr, (ch, _) = _fabric_pair(mtu=64, budget_flits=1)
    ch.push(jnp.zeros(64, jnp.uint8), sweep=0)       # 1 flit
    assert ch.in_flight == 1 and not ch.head_visible(0)
    done = tr.step(0)                                # flit crosses sweep 0
    for mid, ci in done:
        ch.on_delivered(mid, 0)
    assert ch.in_flight == 0
    assert not ch.head_visible(0) and ch.head_visible(1)


def test_contended_channels_account_exact_flit_bytes():
    """Both channels' measured bytes equal the flit-sum the shared link
    carried, even while contending at depth >= 2."""
    tr, (ca, cb) = _fabric_pair(depth=4, mtu=64, budget_flits=1)
    sweep = 0
    for t in range(3):
        ca.push(jnp.zeros(100, jnp.uint8), sweep)    # 2 flits (100B @ 64)
        cb.push(jnp.zeros(64, jnp.uint8), sweep)     # 1 flit
        for mid, ci in tr.step(sweep):
            (ca if ci == 0 else cb).on_delivered(mid, sweep)
        sweep += 1
    while tr.active:
        for mid, ci in tr.step(sweep):
            (ca if ci == 0 else cb).on_delivered(mid, sweep)
        sweep += 1
    assert ca.stats.measured_bytes == ca.stats.net_delivered_bytes == 300
    assert cb.stats.measured_bytes == cb.stats.net_delivered_bytes == 192
    # The one physical link carried every byte of both channels (1 hop).
    assert tr.counters[0].bytes == 300 + 192
    assert tr.counters[0].flits == 3 * (2 + 1)
    # FIFO semantics preserved: tokens pop in push order once visible.
    assert ca.occupancy == 3 and ca.head_visible(sweep)


def test_contended_run_reports_stalls_and_conservation():
    """End-to-end: two crossings share a starved link; the execution report
    shows credit stalls on the fabric and exact conservation."""
    import jax.numpy as jnp

    from repro.compiler import CompileOptions, compile as tapa_compile
    from repro.core import ResourceProfile, Task, TaskGraph
    from repro.core.topology import (ALVEO_U55C, Cluster, DaisyChain,
                                     ETHERNET_100G)
    from repro.exec import ProgramBinding, SOURCE_KEY, execute
    from repro.net import NetConfig, cluster_fabric

    g = TaskGraph("contend")
    for n in ("a", "b", "c", "d"):
        g.add_task(Task(n, ResourceProfile({"LUT": 1000.0})))
    g.add_channel("a", "b", 4096, bytes_per_step=512.0)
    g.add_channel("c", "d", 4096, bytes_per_step=512.0)
    cluster = Cluster(ALVEO_U55C, DaisyChain(3))
    design = tapa_compile(g, cluster, CompileOptions(
        pins={"a": 0, "b": 2, "c": 1, "d": 2},
        fabric=cluster_fabric(cluster),
        passes=("normalize_units", "partition", "pipeline_interconnect")))
    T = 6
    xs = [jnp.full((128,), float(t)) for t in range(T)]    # 512 B tokens
    binding = ProgramBinding(
        graph=g, iterations=T,
        programs={"a": lambda i: i[SOURCE_KEY], "b": lambda i: i["a"],
                  "c": lambda i: i[SOURCE_KEY], "d": lambda i: i["c"]},
        source_inputs={"a": xs, "c": xs})
    cfg = NetConfig(mtu_bytes=64, link_credits=2,
                    sweep_time_s=64 / ETHERNET_100G.bandwidth_Bps)
    rep = execute(design, binding, net_config=cfg).report
    agree = rep.agreement()
    assert agree["net_delivery_match"] and agree["link_conservation"]
    # a->b transits 0->1->2 contending with c->d on 1->2: the backlog at
    # the shared link stalls the upstream hop's credits.
    assert sum(l.stalled_flits for l in rep.congestion.links) > 0
    assert all(c.max_occupancy <= c.depth for c in rep.channels)
    for c in rep.channels:
        assert c.net_bytes == c.net_delivered_bytes == T * 512
