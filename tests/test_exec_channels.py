"""Unit tests for the executor's bounded FIFO channels (repro.exec)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import Channel
from repro.exec import FifoChannel, token_bytes


def _ch(depth=2, latency=1, src_dev=0, dst_dev=0, width=512):
    gch = Channel("a", "b", width, bytes_per_step=64.0)
    gch.depth = depth
    return FifoChannel(0, gch, src_dev, dst_dev, latency=latency)


def test_capacity_bounds_pushes():
    ch = _ch(depth=2)
    ch.push(jnp.zeros(4), sweep=0)
    ch.push(jnp.zeros(4), sweep=0)
    assert ch.full
    with pytest.raises(RuntimeError, match="full"):
        ch.push(jnp.zeros(4), sweep=0)
    assert ch.stats.blocked_pushes == 1
    assert ch.stats.max_occupancy == 2


def test_latency_gates_visibility():
    ch = _ch(depth=4, latency=3)
    ch.push(jnp.arange(4.0), sweep=0)
    for sweep in (0, 1, 2):
        assert not ch.head_visible(sweep)
    assert ch.head_visible(3)
    out = ch.pop(3)
    np.testing.assert_array_equal(np.asarray(out), np.arange(4.0))


def test_pop_unripe_raises():
    ch = _ch(depth=2, latency=2)
    ch.push(jnp.zeros(2), sweep=5)
    with pytest.raises(RuntimeError, match="empty/unripe"):
        ch.pop(5)
    assert ch.stats.empty_pops == 1


def test_fifo_order_preserved():
    ch = _ch(depth=3)
    for i in range(3):
        ch.push(jnp.full((2,), float(i)), sweep=i)
    got = [float(ch.pop(10)[0]) for _ in range(3)]
    assert got == [0.0, 1.0, 2.0]


def test_inter_device_measures_bytes():
    ch = _ch(depth=2, src_dev=0, dst_dev=1)
    assert ch.inter_device and ch.eager_transfer
    tok = {"x": jnp.zeros((4, 4), jnp.float32), "y": jnp.zeros(2)}
    ch.push(tok, sweep=0)
    assert ch.stats.measured_bytes == token_bytes(tok) == 4 * 4 * 4 + 2 * 4


def test_intra_device_measures_nothing():
    ch = _ch(depth=2, src_dev=1, dst_dev=1)
    assert not ch.inter_device
    ch.push(jnp.zeros((8, 8)), sweep=0)
    assert ch.stats.measured_bytes == 0


def test_depth1_disables_double_buffering():
    """§4.6: a depth-1 inter-device FIFO cannot overlap its transfer."""
    assert _ch(depth=2, dst_dev=1).eager_transfer
    assert not _ch(depth=1, dst_dev=1).eager_transfer
    assert not _ch(depth=4).eager_transfer          # intra-device: no move


def test_prime_deposits_visible_token():
    ch = _ch(depth=2, latency=4)
    ch.prime(jnp.ones(3))
    assert ch.head_visible(0)       # primed tokens are visible at once
    np.testing.assert_array_equal(np.asarray(ch.pop(0)), np.ones(3))


def test_capacity_validation():
    gch = Channel("a", "b", 512)
    gch.depth = 0
    with pytest.raises(ValueError, match="capacity"):
        FifoChannel(0, gch, 0, 0)
    gch.depth = 2
    with pytest.raises(ValueError, match="latency"):
        FifoChannel(0, gch, 0, 0, latency=0)
