"""repro.obs.attrib / slo / diff — the per-tenant cost ledger, the online
SLO monitor, and the regression diff gate.

The attribution contract, asserted end to end on real tenant co-runs:

* every ledger row's columns sum **bit-exactly** (integer equality) to
  the global transport / memory / critical-path / registry totals —
  clean, lossy, and kill paths alike (``assert_ledger_consistent``);
* on a lossy shared fabric both tenants are charged retransmissions and
  the per-flow fault columns reconcile with the link counters exactly;
* a :class:`DeviceKill` charges its cancelled bytes and restore sweeps
  to the killed tenant's lineage and **exactly zero** fault cost to its
  peers (``assert_peers_uncharged``);
* the :class:`SLOMonitor` is transparent (a monitored run is
  bit-identical to an unmonitored one), raises debounced ``slo_alert``
  events into the same trace (visible in the Chrome export), and feeds
  live burn rates into admission control;
* the JSONL trace writer round-trips tuple-for-tuple;
* :func:`diff_registries` / :func:`diff_against_baseline` flag drift,
  tolerate within-tolerance change, fail on vanished series, and treat
  new series as informational.
"""
import json

import pytest

from repro.apps import APPS
from repro.compiler import CompileOptions, compile as tapa_compile
from repro.core import fpga_ring_cluster
from repro.net import NetConfig, cluster_fabric
from repro.net.faults import FaultModel, LinkFaults
from repro.obs import (MetricsRegistry, SLOMonitor, Tracer, analyze,
                       assert_ledger_consistent, assert_peers_uncharged,
                       build_ledger, diff_against_baseline, diff_registries,
                       lineage_root, make_baseline, read_jsonl,
                       substrate_metrics, to_chrome_trace, to_jsonl,
                       validate_chrome_trace, write_jsonl)
from repro.tenants import (SLO, DeviceKill, Tenant, TenantServer,
                           bit_identical)
from repro.tenants.slo import ADMIT, REJECT, AdmissionController
from repro.tenants.traffic import Request

_OPTS = CompileOptions(balance_kind="LUT", balance_tol=0.8,
                       exact_limit=1500, floorplan_devices=(0,))
_SPECS = {"a": {"seed": 0}, "b": {"seed": 7}}


@pytest.fixture(scope="module")
def designs():
    graphs = {n: APPS["stencil"].build_graph(2) for n in _SPECS}
    return {n: tapa_compile(graphs[n], fpga_ring_cluster(2), _OPTS)
            for n in _SPECS}


def _tenants(designs):
    return [Tenant("a", designs["a"], device_map=[0, 2],
                   slo=SLO(1e-3, weight=2.0), inputs=_SPECS["a"]),
            Tenant("b", designs["b"], device_map=[0, 1],
                   slo=SLO(1e-3, weight=1.0), inputs=_SPECS["b"])]


@pytest.fixture(scope="module")
def clean_run(designs):
    """Unmonitored baseline + monitored traced co-run on a clean fabric."""
    base = TenantServer(cluster_fabric(fpga_ring_cluster(4)),
                        _tenants(designs)).run()
    tracer = Tracer()
    server = TenantServer(cluster_fabric(fpga_ring_cluster(4)),
                          _tenants(designs), tracer=tracer)
    # A vanishingly small latency limit makes every completion an SLO
    # breach, so the alert path is exercised on a healthy run.
    monitor = SLOMonitor(window=32, latency_limit_s=1e-9)
    out = server.run(monitor=monitor)
    return base, server, out, tracer, monitor


@pytest.fixture(scope="module")
def lossy_run(designs):
    tracer = Tracer()
    fm = FaultModel(seed=3, default=LinkFaults(drop=0.10, corrupt=0.05),
                    fail_threshold=None)
    server = TenantServer(cluster_fabric(fpga_ring_cluster(4)),
                          _tenants(designs),
                          net_config=NetConfig(faults=fm), tracer=tracer)
    out = server.run()
    return server, out, tracer


@pytest.fixture(scope="module")
def kill_run(designs):
    tracer = Tracer()
    server = TenantServer(cluster_fabric(fpga_ring_cluster(4)),
                          _tenants(designs), tracer=tracer)
    out = server.run(faults=[DeviceKill(device=2, sweep=2)])
    return server, out, tracer


# ---------------------------------------------------------------------------
# The cost ledger.
# ---------------------------------------------------------------------------

def test_ledger_sums_exactly_on_clean_corun(clean_run):
    _, server, out, tracer, _ = clean_run
    crit = analyze(tracer, sweeps=out.sweeps)
    ledger = build_ledger(server, crit=crit)
    assert_ledger_consistent(ledger, server, crit=crit,
                             registry=substrate_metrics(server))
    assert {r.tenant for r in ledger.rows} == {"a", "b"}
    # Columns the totals() view must reproduce, exactly.
    totals = ledger.totals()
    assert totals["net_bytes"] == sum(r.net_bytes for r in ledger.rows)
    assert totals["net_bytes"] == \
        sum(c.bytes for c in server.transport.counters)
    # No faults on a clean fabric: every fault column is zero.
    for r in ledger.rows:
        assert all(v == 0 for v in r.fault_cost().values()), r.tenant
    doc = ledger.to_json()
    assert doc["format"] == "cost-ledger/v1"
    assert len(doc["rows"]) == len(ledger.rows)
    # The registry projection labels rows by tenant and lineage.
    reg = ledger.to_registry()
    for r in ledger.rows:
        assert reg.value("attrib.tenant.net_bytes", 0, tenant=r.tenant,
                         lineage=r.lineage) == r.net_bytes


def test_ledger_lossy_charges_both_tenants_exactly(lossy_run):
    server, out, tracer = lossy_run
    crit = analyze(tracer, sweeps=out.sweeps)
    ledger = build_ledger(server, crit=crit)
    assert_ledger_consistent(ledger, server, crit=crit,
                             registry=substrate_metrics(server))
    by = ledger.by_lineage()
    # Both tenants share lossy links, so both pay retransmissions — and
    # the split sums back to the global counter bit-exactly.
    assert by["a"]["retransmit_bytes"] > 0
    assert by["b"]["retransmit_bytes"] > 0
    assert by["a"]["retransmit_bytes"] + by["b"]["retransmit_bytes"] == \
        sum(c.retransmit_bytes for c in server.transport.counters)
    assert ledger.totals()["fault_sweeps"] > 0


def test_kill_charges_victim_lineage_not_peers(kill_run):
    server, out, tracer = kill_run
    assert out.record("a").status == "killed"
    crit = analyze(tracer, sweeps=out.sweeps)
    ledger = build_ledger(server, crit=crit)
    assert_ledger_consistent(ledger, server, crit=crit)
    assert_peers_uncharged(ledger, ["a"])
    by = ledger.by_lineage()
    # The victim's lineage pays the kill: cancelled in-flight bytes and
    # the recovered incarnation's restore sweeps.
    assert by["a"]["cancelled_bytes"] > 0
    assert by["a"]["restore_sweeps"] > 0
    # The peer pays exactly nothing, in every fault column.
    for col in ("cancelled_bytes", "restore_sweeps", "fault_sweeps",
                "retransmit_bytes", "backoff_sweeps", "arq_stalls"):
        assert by["b"][col] == 0, col
    # Both incarnations fold into one lineage row set.
    assert lineage_root("a+recovered") == "a"
    assert {r.lineage for r in ledger.rows} == {"a", "b"}
    assert sum(1 for r in ledger.rows if r.lineage == "a") == 2


def test_peers_uncharged_raises_on_charged_peer(lossy_run):
    server, out, tracer = lossy_run
    ledger = build_ledger(server, crit=analyze(tracer, sweeps=out.sweeps))
    # On the lossy run *both* tenants carry fault cost, so naming only
    # one of them as the victim must fail the zero-charge assert.
    with pytest.raises(AssertionError):
        assert_peers_uncharged(ledger, ["a"])


# ---------------------------------------------------------------------------
# The online SLO monitor.
# ---------------------------------------------------------------------------

def test_monitor_is_transparent_and_raises_alerts(clean_run):
    base, _, out, tracer, monitor = clean_run
    # Bit-identity: the monitor only reads the trace and appends alerts.
    assert out.sweeps == base.sweeps
    for n in _SPECS:
        assert bit_identical(out.record(n).result.outputs,
                             base.record(n).result.outputs), n
    # The tiny latency limit fired p99 alerts for both tenants...
    assert monitor.alerts
    assert {a["tenant"] for a in monitor.alerts} == {"a", "b"}
    assert all(a["metric"] == "p99_latency_s" for a in monitor.alerts)
    # ...into the shared trace, rendered in the Chrome export.
    assert tracer.count("slo_alert") == len(monitor.alerts)
    doc = to_chrome_trace(tracer)
    validate_chrome_trace(doc)
    slo_events = [e for e in doc["traceEvents"] if e.get("cat") == "slo"]
    assert len(slo_events) == len(monitor.alerts)
    # The summary is JSON-ready and covers both tenants.
    summary = monitor.summary(out.sweeps)
    json.dumps(summary)
    assert set(summary["tenants"]) == {"a", "b"}
    for snap in summary["tenants"].values():
        assert snap["completed"] >= 0
        assert snap["p99_latency_s"] >= snap["p50_latency_s"] >= 0.0


def test_monitor_alerts_are_debounced(clean_run):
    _, _, out, _, monitor = clean_run
    # Cooldown: per (tenant, metric), consecutive alerts are >= cooldown
    # sweeps apart.
    seen = {}
    for a in monitor.alerts:
        key = (a["tenant"], a["metric"])
        if key in seen:
            assert a["sweep"] - seen[key] >= monitor.cooldown, key
        seen[key] = a["sweep"]


def test_monitor_burn_feeds_admission_control():
    slo = SLO(1e-3, weight=1.0, deadline_factor=2.0)
    ctl = AdmissionController({0: slo}, {0: 1e6})
    # A request feasible at the declared rate is admitted...
    assert ctl.offer(Request(rid=0, tenant=0, t_arrival=0.0,
                             size=1000.0), 0.0) == ADMIT
    ctl.complete(Request(rid=0, tenant=0, t_arrival=0.0, size=1000.0))
    # ...but after the monitor reports a 5x budget burn the effective
    # rate is discounted 5x and the same offer is shed at the door.
    ctl.note_burn(0, 5.0)
    assert ctl.rate_scale(0) == pytest.approx(0.2)
    assert ctl.offer(Request(rid=1, tenant=0, t_arrival=0.0,
                             size=1000.0), 0.0) == REJECT
    # Burn back under 1.0 restores the declared rate.
    ctl.note_burn(0, 0.5)
    assert ctl.rate_scale(0) == 1.0
    # Unknown tenants are ignored, not KeyErrored.
    ctl.note_burn(99, 7.0)


def test_monitor_rejects_bad_config():
    with pytest.raises(ValueError):
        SLOMonitor(window=0)
    with pytest.raises(ValueError):
        SLOMonitor(burn_alert=0.0)
    with pytest.raises(ValueError):
        SLOMonitor(cooldown=-1)


# ---------------------------------------------------------------------------
# JSONL trace streaming.
# ---------------------------------------------------------------------------

def test_jsonl_round_trips_tuple_for_tuple(clean_run, tmp_path):
    _, _, _, tracer, _ = clean_run
    path = tmp_path / "trace.jsonl"
    n = write_jsonl(tracer, str(path))
    assert n == len(tracer.events)
    text = to_jsonl(tracer)
    assert len(text.splitlines()) == len(tracer.events) + 1   # + header
    header = json.loads(text.splitlines()[0])
    assert header["format"] == "repro-obs-jsonl/v1"
    assert header["events"] == len(tracer.events)
    back = read_jsonl(str(path))
    assert back.events == tracer.events
    assert back.link_devs == tracer.link_devs
    # The rehydrated trace still exports a valid Chrome document.
    validate_chrome_trace(to_chrome_trace(back))


# ---------------------------------------------------------------------------
# Regression diffing.
# ---------------------------------------------------------------------------

def _reg(**vals):
    r = MetricsRegistry()
    for name, v in vals.items():
        r.counter_add(name.replace("_", "."), v, link=0)
    return r


def test_diff_identical_registries_is_ok():
    d = diff_registries(_reg(net_bytes=100), _reg(net_bytes=100))
    assert d.ok and not d.violations and not d.removed
    assert d.compared == 1


def test_diff_flags_drift_beyond_tolerance():
    d = diff_registries(_reg(net_bytes=100), _reg(net_bytes=120))
    assert not d.ok
    assert d.violations[0].metric == "net.bytes"
    assert d.violations[0].kind == "drift"
    assert "DRIFT" in d.format()
    # The same change passes inside a 20% relative tolerance.
    d2 = diff_registries(_reg(net_bytes=100), _reg(net_bytes=120),
                         tolerances={"net.bytes": 0.2})
    assert d2.ok


def test_diff_removed_series_fails_added_is_informational():
    base = _reg(net_bytes=100, mem_bytes=50)
    cand = _reg(net_bytes=100, new_metric=7)
    d = diff_registries(base, cand)
    assert not d.ok
    assert [x.metric for x in d.removed] == ["mem.bytes"]
    assert [x.metric for x in d.added] == ["new.metric"]
    # Added alone does not fail the gate.
    d2 = diff_registries(_reg(net_bytes=100), cand)
    assert d2.ok and d2.added


def test_diff_ignore_list_skips_nondeterministic_series():
    d = diff_registries(_reg(busy_s=100), _reg(busy_s=999),
                        ignore=["busy.s"])
    assert d.ok and d.ignored == 1 and d.compared == 0


def test_diff_against_baseline_document(tmp_path):
    base_doc = make_baseline({"stencil": _reg(net_bytes=100)},
                             tolerances={"net.bytes": 0.05},
                             ignore=["exec.device.busy_s"])
    assert base_doc["format"] == "obs-baseline/v1"
    # Within tolerance: ok.  Beyond: drift.  Missing app: removed.
    out = diff_against_baseline(base_doc, {"stencil": _reg(net_bytes=103)})
    assert out["stencil"].ok
    out = diff_against_baseline(base_doc, {"stencil": _reg(net_bytes=120)})
    assert not out["stencil"].ok
    out = diff_against_baseline(base_doc, {})
    assert not out["stencil"].ok and out["stencil"].removed
    # The documents round-trip through JSON files unchanged.
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps(base_doc))
    from repro.obs.diff import load_json
    assert load_json(str(p)) == base_doc
    with pytest.raises(ValueError):
        diff_against_baseline({"format": "bogus"}, {})


def test_diff_report_is_json_ready():
    d = diff_registries(_reg(net_bytes=100), _reg(net_bytes=120))
    doc = d.to_json()
    json.dumps(doc)
    assert doc["format"] == "obs-diff/v1"
    assert doc["ok"] is False and doc["violations"]
