"""End-to-end tests for the dataflow executor (repro.exec).

Numerics parity: every paper app, compiled onto 2- and 4-device rings and
run through the executor, must reproduce its single-device Pallas/jnp
reference.  Accounting: the measured inter-device traffic must land on
exactly the channels the partitioner's Eq. 2 objective charged.
Regression: a FIFO clamped below its §4.6 balanced depth is caught by the
starvation detector, while the compiler's balanced depths run clean.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import APPS
from repro.compiler import CompileOptions, compile as tapa_compile
from repro.core import ResourceProfile, Task, TaskGraph, fpga_ring_cluster
from repro.exec import (DeadlockError, ProgramBinding, SOURCE_KEY,
                        StarvationError, bind_programs, execute)

# Small exact_limit keeps the larger graphs on the fast recursive-bisect
# path; the executor only needs *a* valid partition, not the optimum.
_OPTS = CompileOptions(balance_kind="LUT", balance_tol=0.8,
                       floorplan_devices=(0,), exact_limit=1500,
                       partition_time_limit=20.0)


def _compile(app: str, ndev: int):
    graph = APPS[app].build_graph(ndev)
    return tapa_compile(graph, fpga_ring_cluster(ndev), _OPTS)


@pytest.mark.parametrize(
    "ndev", [2, pytest.param(4, marks=pytest.mark.slow)])
@pytest.mark.parametrize("app", ["stencil", "pagerank", "knn", "cnn"])
def test_numerics_parity(app, ndev):
    design = _compile(app, ndev)
    binding = bind_programs(design.graph)
    result = execute(design, binding)
    expected = binding.reference()
    got = result.outputs
    if app == "knn":                      # compare distances; ties may
        got, expected = got[0], expected[0]   # reorder indices
    err = float(jnp.max(jnp.abs(got - expected)))
    assert err <= binding.atol, (app, ndev, err)


@pytest.mark.parametrize("app", ["stencil", "pagerank", "knn", "cnn"])
def test_measured_traffic_matches_partition_accounting(app):
    design = _compile(app, 2)
    report = execute(design).report
    agree = report.agreement()
    assert agree["cut_set_match"], report.summary()["comm"]
    assert agree["comm_cost_match"], report.summary()["comm"]
    assert report.measured_inter_bytes > 0
    # Every task fired `iterations` times on its assigned device.
    assert sum(report.device_fired.values()) == \
        report.iterations * len(design.graph.tasks)
    # Balanced §4.6 depths: the pipeline never starved.
    assert not report.starvation_events


def test_executor_respects_channel_depths():
    """Occupancy stays within the compiled FIFO capacities."""
    design = _compile("knn", 4)
    report = execute(design).report
    for tr in report.channels:
        assert 0 < tr.tokens
        assert tr.max_occupancy <= tr.depth


# ---------------------------------------------------------------------------
# Deadlock / starvation regression (§4.6 cut-set balancing).
# ---------------------------------------------------------------------------

def _forkjoin_graph():
    """a → b → c plus a direct a → c edge: reconvergent paths whose latency
    differs when b lands on the remote device."""
    g = TaskGraph("forkjoin")
    for n in ("a", "b", "c"):
        g.add_task(Task(n, ResourceProfile({"LUT": 1000.0})))
    g.add_channel("a", "b", 512, bytes_per_step=64.0)
    g.add_channel("b", "c", 512, bytes_per_step=64.0)
    g.add_channel("a", "c", 512, bytes_per_step=64.0)
    return g


def _forkjoin_binding(g, T=8):
    xs = [jnp.full((4,), float(t)) for t in range(T)]
    programs = {"a": lambda i: i[SOURCE_KEY],
                "b": lambda i: i["a"] + 1.0,
                "c": lambda i: i["a"] + i["b"]}
    return ProgramBinding(
        graph=g, programs=programs, iterations=T,
        source_inputs={"a": xs},
        finalize=lambda s: jnp.stack(s["c"]),
        reference=lambda: jnp.stack([2.0 * x + 1.0 for x in xs]))


def _forkjoin_design(g):
    return tapa_compile(g, fpga_ring_cluster(2), CompileOptions(
        balance_kind="LUT", balance_tol=2.0,
        pins={"a": 0, "c": 0, "b": 1},
        passes=("normalize_units", "partition", "pipeline_interconnect",
                "schedule")))


def test_balanced_depths_run_clean():
    g = _forkjoin_graph()
    design = _forkjoin_design(g)
    # The §4.6 pass deepened the short a→c path to absorb the slack.
    depths = {(c.src, c.dst): c.depth for c in g.channels}
    assert depths[("a", "c")] > depths[("a", "b")]
    result = execute(design, _forkjoin_binding(g))
    binding = _forkjoin_binding(g)
    np.testing.assert_allclose(np.asarray(result.outputs),
                               np.asarray(binding.reference()), atol=1e-6)


def test_unbalanced_fifo_caught_by_starvation_detector():
    g = _forkjoin_graph()
    design = _forkjoin_design(g)
    # Clamp the short path's FIFO below its balanced depth: the join must
    # starve behind it instead of silently throttling.
    direct = next(c for c in g.channels if (c.src, c.dst) == ("a", "c"))
    direct.depth = 1
    with pytest.raises(StarvationError, match=r"join 'c' .* a->c"):
        execute(design, _forkjoin_binding(g))


def test_hard_deadlock_diagnosed():
    """An unseeded back edge can never fire — the executor must say why."""
    g = TaskGraph("cycle")
    for n in ("x", "y"):
        g.add_task(Task(n, ResourceProfile({"LUT": 1000.0})))
    g.add_channel("x", "y", 512)
    g.add_channel("y", "x", 512, back=True)
    design = tapa_compile(g, fpga_ring_cluster(2), CompileOptions(
        balance_kind="LUT", balance_tol=2.0,
        passes=("normalize_units", "partition", "pipeline_interconnect")))
    binding = ProgramBinding(
        graph=g, iterations=2,
        programs={"x": lambda i: i["y"], "y": lambda i: i["x"]},
        prime={})                 # deliberately missing the seed token
    with pytest.raises(DeadlockError, match="deadlock"):
        execute(design, binding)


# ---------------------------------------------------------------------------
# Binding plumbing.
# ---------------------------------------------------------------------------

def test_execute_entry_point_on_artifact():
    design = _compile("stencil", 2)
    result = design.execute(inputs={"h": 32, "w": 32, "streams": 2})
    assert result.outputs.shape == (2, 32, 32)
    assert result.report.iterations == 2


def test_bind_programs_rejects_unknown_graph():
    g = TaskGraph("mystery-app")
    g.add_task(Task("t", ResourceProfile({"LUT": 1.0})))
    with pytest.raises(KeyError, match="no program binding"):
        bind_programs(g)


def test_binding_validates_coverage():
    g = _forkjoin_graph()
    with pytest.raises(ValueError, match="no program bound"):
        ProgramBinding(graph=g, programs={"a": lambda i: i},
                       iterations=1).validate()


def test_parallel_channels_rejected():
    """Two channels between one task pair would shadow a token — refuse."""
    g = TaskGraph("twin")
    for n in ("p", "q"):
        g.add_task(Task(n, ResourceProfile({"LUT": 1000.0})))
    g.add_channel("p", "q", 512)
    g.add_channel("p", "q", 256)
    design = tapa_compile(g, fpga_ring_cluster(2), CompileOptions(
        balance_kind="LUT", balance_tol=2.0,
        passes=("normalize_units", "partition", "pipeline_interconnect")))
    binding = ProgramBinding(
        graph=g, iterations=1,
        programs={"p": lambda i: i[SOURCE_KEY], "q": lambda i: i["p"]},
        source_inputs={"p": [jnp.zeros(2)]})
    with pytest.raises(ValueError, match="parallel channels"):
        execute(design, binding)
