"""repro.tenants — multi-tenant serving over one shared fabric.

Covers the tentpole's acceptance criteria: two tenants co-running over one
shared ``FabricTransport`` stay bit-identical to their solo runs with
exact per-tenant link-byte conservation; the weighted-fair fluid model
keeps an oversubscribed tenant from starving a peer below 90% of its fair
share; a device kill mid-flight drains the victim without perturbing the
survivor, and the victim re-admits onto its surviving devices after a
re-compile.  Plus the traffic generator's determinism and the admission
controller's admit/queue/reject + priority-aging semantics.
"""
import dataclasses

import numpy as np
import pytest

from repro.apps import APPS
from repro.compiler import CompileOptions, compile as tapa_compile
from repro.core import Bus, DaisyChain, Ring, fpga_ring_cluster
from repro.core.topology import Cluster, ALVEO_U55C
from repro.exec import bind_programs, execute
from repro.net import cluster_fabric
from repro.net.transport import NetConfig
from repro.tenants import (ADMIT, QUEUE, REJECT, SLO, AdmissionController,
                           DeviceKill, RecoveryPlan, Tenant, TenantLoad,
                           TenantServer, TrafficConfig, bit_identical,
                           fair_share, generate, isolation_check,
                           load_sweep, merge, offered_load, plan_recovery,
                           recompile, shrink_cluster, simulate)

# ---------------------------------------------------------------------------
# Traffic: seeded, open-loop, deterministic.
# ---------------------------------------------------------------------------

_TRAFFIC = TrafficConfig(rate_rps=200.0, mean_size=4096.0, duration_s=2.0)


def test_traffic_is_deterministic_per_seed_and_tenant():
    a1 = generate(_TRAFFIC, 0, np.random.default_rng([7, 0]))
    a2 = generate(_TRAFFIC, 0, np.random.default_rng([7, 0]))
    b = generate(_TRAFFIC, 1, np.random.default_rng([7, 1]))
    assert a1 == a2
    assert a1 != b
    assert all(r.tenant == 0 for r in a1)
    assert all(r.size > 0 for r in a1)
    arr = [r.t_arrival for r in a1]
    assert arr == sorted(arr) and arr[-1] <= _TRAFFIC.duration_s


def test_traffic_rate_and_mean_size_are_calibrated():
    cfg = dataclasses.replace(_TRAFFIC, duration_s=50.0)
    reqs = generate(cfg, 0, np.random.default_rng([3, 0]))
    rate = len(reqs) / cfg.duration_s
    assert rate == pytest.approx(cfg.rate_rps, rel=0.1)
    mean = np.mean([r.size for r in reqs])
    assert mean == pytest.approx(cfg.mean_size, rel=0.2)
    assert offered_load(reqs, cfg.duration_s) == pytest.approx(
        cfg.rate_rps * cfg.mean_size, rel=0.25)
    assert offered_load([], 0.0) == 0.0


def test_traffic_scaled_and_merge():
    doubled = _TRAFFIC.scaled(2.0)
    assert doubled.rate_rps == 2 * _TRAFFIC.rate_rps
    a = generate(_TRAFFIC, 0, np.random.default_rng([1, 0]))
    b = generate(_TRAFFIC, 1, np.random.default_rng([1, 1]))
    m = merge([a, b])
    assert len(m) == len(a) + len(b)
    assert [r.t_arrival for r in m] == sorted(r.t_arrival for r in m)


def test_profiles_modulate_the_rate():
    diurnal = dataclasses.replace(_TRAFFIC, profile="diurnal", swing=0.5,
                                  period_s=10.0)
    assert diurnal.rate_at(2.5) > diurnal.rate_at(0.0) > diurnal.rate_at(7.5)
    ramp = dataclasses.replace(_TRAFFIC, profile="ramp", swing=0.9,
                               duration_s=20.0)
    assert ramp.rate_at(20.0) > ramp.rate_at(0.0)
    # A steep ramp skews the stream late: its median arrival lands well
    # past the flat stream's mid-horizon median.
    flat = dataclasses.replace(_TRAFFIC, duration_s=20.0)
    mf = np.median([r.t_arrival
                    for r in generate(flat, 0, np.random.default_rng([5, 0]))])
    mr = np.median([r.t_arrival
                    for r in generate(ramp, 0, np.random.default_rng([5, 0]))])
    assert mr > mf + 2.0


# ---------------------------------------------------------------------------
# Admission: admit / queue / reject + deadline-aware priority aging.
# ---------------------------------------------------------------------------

def _req(rid, tenant, t, size=1000.0):
    from repro.tenants import Request
    return Request(rid=rid, tenant=tenant, t_arrival=t, size=size)


def test_admission_three_way_call():
    slo = SLO(target_latency_s=1.0, max_inflight=1, deadline_factor=3.0)
    ctrl = AdmissionController({0: slo}, {0: 1000.0})  # 1 req/s of work
    assert ctrl.offer(_req(0, 0, 0.0), 0.0) == ADMIT
    # One second of backlog ahead: finishes at ~2.1s, inside the 3.1s
    # deadline — but the single service slot is taken, so it queues.
    assert ctrl.offer(_req(1, 0, 0.1), 0.1) == QUEUE
    # Three more seconds of work could only finish at ~5.2s > 3.2s.
    assert ctrl.offer(_req(2, 0, 0.2, size=3000.0), 0.2) == REJECT
    assert ctrl.stats[0].admitted == 1
    assert ctrl.stats[0].queued == 1
    assert ctrl.stats[0].rejected == 1


def test_priority_aging_prefers_tight_slo():
    tight = SLO(target_latency_s=0.1, max_inflight=1, deadline_factor=40.0)
    loose = SLO(target_latency_s=10.0, max_inflight=1, deadline_factor=4.0)
    ctrl = AdmissionController({0: loose, 1: tight},
                              {0: 1e6, 1: 1e6})
    assert ctrl.offer(_req(0, 0, 0.0), 0.0) == ADMIT
    assert ctrl.offer(_req(1, 1, 0.0), 0.0) == ADMIT
    # The loose request has waited longer in wall time...
    assert ctrl.offer(_req(2, 0, 0.1), 0.1) == QUEUE
    assert ctrl.offer(_req(3, 1, 0.3), 0.3) == QUEUE
    ctrl.complete(_req(0, 0, 0.0))
    ctrl.complete(_req(1, 1, 0.0))
    # ...but age normalized by target ranks the tight one far ahead.
    first = ctrl.release(1.0)
    assert first.tenant == 1 and first.rid == 3
    second = ctrl.release(1.0)
    assert second.tenant == 0 and second.rid == 2


def test_expired_pending_is_shed_as_rejected():
    slo = SLO(target_latency_s=0.1, max_inflight=1, deadline_factor=2.0)
    ctrl = AdmissionController({0: slo}, {0: 1e9})
    assert ctrl.offer(_req(0, 0, 0.0), 0.0) == ADMIT
    assert ctrl.offer(_req(1, 0, 0.0), 0.0) == QUEUE
    ctrl.complete(_req(0, 0, 0.0))
    assert ctrl.release(10.0) is None          # deadline long gone
    assert ctrl.stats[0].rejected == 1
    assert ctrl.pending == 0


# ---------------------------------------------------------------------------
# Fluid serving simulation: SLO curves + the isolation invariant.
# ---------------------------------------------------------------------------

def _load(name, rate_frac, capacity, weight=1.0, mean=65536.0):
    share = capacity * weight / 2.0
    return TenantLoad(
        name=name,
        slo=SLO(target_latency_s=16 * mean / share, weight=weight,
                max_inflight=8),
        traffic=TrafficConfig(rate_rps=rate_frac * share / mean,
                              mean_size=mean, duration_s=2.0))


def test_simulate_underload_meets_slo():
    cap = 1e8
    res = simulate({0: _load("a", 0.3, cap), 1: _load("b", 0.3, cap)}, cap,
                   seed=1)
    for t in (0, 1):
        st = res.tenants[t]
        assert st.completed > 0
        assert st.rejected <= 0.01 * st.offered
        assert st.completed_in_slo >= 0.99 * st.completed
        assert st.goodput_bytes > 0


def test_load_sweep_goodput_folds_over_at_saturation():
    cap = 1e8
    loads = {0: _load("a", 1.0, cap), 1: _load("b", 1.0, cap)}
    rows = load_sweep(loads, cap, [0.25, 1.0, 4.0], seed=2)
    assert [r["load_factor"] for r in rows] == [0.25, 1.0, 4.0]
    g = [sum(t["goodput_Bps"] for t in r["tenants"].values())
         for r in rows]
    assert g[1] > g[0]                         # more load, more goodput...
    assert g[2] <= cap                          # ...but never above the pipe
    p99 = [r["tenants"]["a"]["p99_latency_s"] for r in rows]
    assert p99[2] >= p99[0]                     # saturation costs latency
    # The overloaded point sheds work at the door instead of serving late.
    assert rows[2]["tenants"]["a"]["rejected"] > 0


def test_isolation_invariant_against_an_oversubscribing_peer():
    iso = isolation_check(1e9, seed=0)
    assert iso["isolated"]
    assert iso["victim_share_frac"] >= 0.9
    assert iso["aggressor"]["rejected"] > 0     # the 2x load is shed


def test_fair_share_is_weight_proportional():
    w = {0: 3.0, 1: 1.0}
    assert fair_share(8e9, w, 0) == pytest.approx(6e9)
    assert fair_share(8e9, w, 1) == pytest.approx(2e9)


# ---------------------------------------------------------------------------
# Recovery: cluster shrink + full re-compile.
# ---------------------------------------------------------------------------

def test_shrink_cluster_topology_families():
    ring = fpga_ring_cluster(4)
    assert isinstance(shrink_cluster(ring, 3).topology, Ring)
    assert isinstance(shrink_cluster(ring, 2).topology, DaisyChain)
    bus = Cluster(ALVEO_U55C, Bus(4))
    shrunk = shrink_cluster(bus, 3)
    assert isinstance(shrunk.topology, Bus)
    assert shrunk.topology.num_devices == 3
    grouped = fpga_ring_cluster(4, devices_per_node=2)
    assert shrink_cluster(grouped, 2).devices_per_node is None


# ---------------------------------------------------------------------------
# The tenant server: shared-substrate co-execution (the acceptance tests).
# ---------------------------------------------------------------------------

_OPTS = CompileOptions(balance_kind="LUT", balance_tol=0.8,
                       exact_limit=1500, floorplan_devices=(0,))
_SPECS = {"a": {"seed": 0}, "b": {"seed": 7}}


@pytest.fixture(scope="module")
def compiled():
    graphs = {n: APPS["stencil"].build_graph(2) for n in _SPECS}
    designs = {n: tapa_compile(graphs[n], fpga_ring_cluster(2), _OPTS)
               for n in _SPECS}
    solo = {n: execute(designs[n], bind_programs(graphs[n], _SPECS[n]),
                       fabric=None) for n in _SPECS}
    return graphs, designs, solo


def _tenants(designs):
    return [
        Tenant("a", designs["a"], device_map=[0, 2],
               slo=SLO(1e-3, weight=2.0), inputs=_SPECS["a"]),
        Tenant("b", designs["b"], device_map=[0, 1],
               slo=SLO(1e-3, weight=1.0), inputs=_SPECS["b"]),
    ]


def test_corun_is_bit_identical_with_exact_conservation(compiled):
    _, designs, solo = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    server = TenantServer(fabric, _tenants(designs))
    out = server.run()
    for n in _SPECS:
        rec = out.record(n)
        assert rec.status == "done"
        assert bit_identical(rec.result.outputs, solo[n].outputs), n
        assert all(rec.result.report.agreement().values()), n
    # Both tenants crossed the shared 0->1 link, and every link's per-flow
    # buckets sum to its total (asserted again inside conservation()).
    assert any(len(c.flow_bytes) >= 2 for c in server.transport.counters)
    cons = out.conservation
    assert cons["exact"]
    assert sum(cons["per_tenant_link_bytes"].values()) \
        == cons["total_link_bytes"]
    assert all(b > 0 for b in cons["per_tenant_link_bytes"].values())
    # Per-tenant congestion reports are scoped to each flow's bytes.
    for n in _SPECS:
        cong = out.record(n).result.report.congestion
        assert sum(l.bytes for l in cong.links) \
            == cons["per_tenant_link_bytes"][n]
        assert cong.kind.endswith(f"flow{out.record(n).flow}")


def test_device_kill_drains_readmits_and_spares_the_peer(compiled):
    graphs, designs, solo = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    server = TenantServer(fabric, _tenants(designs))
    out = server.run(faults=[DeviceKill(device=2, sweep=2)])
    killed = out.record("a")
    assert killed.status == "killed" and killed.killed_at == 2
    recovered = out.record("a+recovered")
    assert recovered.status == "done"
    assert recovered.flow != killed.flow        # fresh incarnation id
    peer = out.record("b")
    assert peer.status == "done"
    assert bit_identical(peer.result.outputs, solo["b"].outputs)
    binding = bind_programs(graphs["a"], _SPECS["a"])
    ref = np.asarray(binding.reference())
    got = np.asarray(recovered.result.outputs)
    assert np.max(np.abs(got - ref)) <= binding.atol
    assert out.conservation["exact"]


def test_kill_without_readmit_leaves_victim_dead(compiled):
    _, designs, _ = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    server = TenantServer(fabric, _tenants(designs))
    out = server.run(faults=[DeviceKill(device=2, sweep=2, readmit=False)])
    assert out.record("a").status == "killed"
    assert out.record("a").recovered_as is None
    assert out.record("b").status == "done"
    with pytest.raises(KeyError):
        out.record("a+recovered")


def test_recompile_survivor_design_is_first_class(compiled):
    _, designs, _ = compiled
    degraded = recompile(designs["a"], 1)
    assert degraded.cluster.topology.num_devices == 1
    assert degraded.partition is not None
    assert set(degraded.partition.assignment.values()) == {0}
    assert degraded.options.fabric is None


def test_duplicate_tenant_names_rejected(compiled):
    _, designs, _ = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    tenants = _tenants(designs)
    tenants[1] = dataclasses.replace(tenants[1], name="a")
    with pytest.raises(ValueError):
        TenantServer(fabric, tenants)


def test_solo_tenant_matches_solo_execution(compiled):
    """One tenant through the server == the plain executor (flow machinery
    is invisible when nobody shares)."""
    _, designs, solo = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    server = TenantServer(fabric, [_tenants(designs)[0]],
                          net_config=NetConfig())
    out = server.run()
    rec = out.record("a")
    assert rec.status == "done"
    assert bit_identical(rec.result.outputs, solo["a"].outputs)
    assert out.conservation["exact"]


# ---------------------------------------------------------------------------
# Recovery planning: restore-over-recompile + the kill edge cases.
# ---------------------------------------------------------------------------

def test_plan_recovery_prefers_restore_when_cluster_survives(tmp_path):
    # No snapshot yet: recompile onto the survivors.
    plan = plan_recovery([0, 2], [], checkpoint_dir=str(tmp_path))
    assert plan.action == "recompile" and plan.ndev == 2
    # A published snapshot + intact placement: restore from the barrier.
    (tmp_path / "step_4").mkdir()
    plan = plan_recovery([0, 2], [], checkpoint_dir=str(tmp_path))
    assert isinstance(plan, RecoveryPlan)
    assert plan.action == "restore" and plan.step == 4 and plan.ndev == 2
    # A permanently dead placement device disqualifies the snapshot.
    plan = plan_recovery([0, 2], [2], checkpoint_dir=str(tmp_path))
    assert plan.action == "recompile" and plan.ndev == 1
    # Nothing survives: the plan says decline (ndev 0), never restore.
    plan = plan_recovery([2], [2], checkpoint_dir=str(tmp_path))
    assert plan.action == "recompile" and plan.ndev == 0


def test_transient_kill_restores_from_barrier(compiled, tmp_path):
    """A transient device kill of a checkpointing tenant restores the SAME
    design from its last sweep barrier (recovered_via='restore') and still
    finishes bit-identical; the un-checkpointed peer is untouched."""
    _, designs, solo = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    tenants = _tenants(designs)
    tenants[0] = dataclasses.replace(tenants[0],
                                     checkpoint_dir=str(tmp_path))
    server = TenantServer(fabric, tenants)
    out = server.run(faults=[DeviceKill(device=2, sweep=4, transient=True)],
                     checkpoint_every=2)
    killed = out.record("a")
    assert killed.status == "killed" and killed.recovered_as == "a+recovered"
    rec = out.record("a+recovered")
    assert rec.status == "done"
    assert rec.recovered_via == "restore"
    assert rec.tenant.device_map == [0, 2]      # same placement, no shrink
    assert rec.tenant.design is designs["a"]    # same design, no recompile
    assert bit_identical(rec.result.outputs, solo["a"].outputs)
    assert bit_identical(out.record("b").result.outputs, solo["b"].outputs)
    assert out.conservation["exact"]


def test_permanent_kill_recompiles_and_labels_it(compiled, tmp_path):
    """Snapshots exist, but the device is permanently gone: the snapshot's
    cluster no longer exists, so recovery recompiles onto survivors."""
    _, designs, _ = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    tenants = _tenants(designs)
    tenants[0] = dataclasses.replace(tenants[0],
                                     checkpoint_dir=str(tmp_path))
    server = TenantServer(fabric, tenants)
    out = server.run(faults=[DeviceKill(device=2, sweep=4)],
                     checkpoint_every=2)
    rec = out.record("a+recovered")
    assert rec.status == "done"
    assert rec.recovered_via == "recompile"
    assert rec.tenant.device_map == [0]
    assert rec.tenant.checkpoint_dir is None    # old snapshots unusable


def test_kill_that_leaves_no_survivors_declines_gracefully(compiled):
    """A kill wiping a tenant's whole placement cannot recompile onto
    anything: recovery raises the named DeadlockError instead of
    admitting a zero-device design or hanging."""
    from repro.exec.executor import DeadlockError
    _, designs, _ = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    one_dev = recompile(designs["a"], 1)
    server = TenantServer(fabric, [
        Tenant("solo", one_dev, device_map=[2], inputs=_SPECS["a"]),
    ])
    with pytest.raises(DeadlockError, match="no surviving devices"):
        server.run(faults=[DeviceKill(device=2, sweep=2)])
    # recompile itself also refuses a zero-device ask.
    with pytest.raises(ValueError):
        recompile(designs["a"], 0)


def test_double_kill_of_same_device_is_idempotent(compiled):
    """The second kill of an already-dead device finds no running victim
    on it: the first incarnation is not re-killed, the recovered one
    (living elsewhere) is untouched, everyone finishes."""
    _, designs, solo = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    server = TenantServer(fabric, _tenants(designs))
    out = server.run(faults=[DeviceKill(device=2, sweep=2),
                             DeviceKill(device=2, sweep=4)])
    killed = out.record("a")
    assert killed.status == "killed" and killed.killed_at == 2
    assert killed.recovered_as == "a+recovered"
    rec = out.record("a+recovered")
    assert rec.status == "done"
    # Exactly one recovered incarnation: the second kill was a no-op.
    assert len([r for r in out.records if r.name.startswith("a")]) == 2
    assert bit_identical(out.record("b").result.outputs, solo["b"].outputs)
    assert out.conservation["exact"]


def test_cancel_flow_twice_is_a_noop(compiled):
    """cancel_flow is idempotent: the second call finds nothing, returns
    nothing, and leaves every counter exactly where the first left it."""
    _, designs, _ = compiled
    fabric = cluster_fabric(fpga_ring_cluster(4))
    server = TenantServer(fabric, _tenants(designs))
    tr = server.transport
    # Drive a few sweeps so flow 0 has traffic in flight, then tear it
    # down twice.
    for rec in server.records:
        pass
    sweep = 0
    while not tr.active and sweep < 16:
        for rec in server.records:
            if rec.state is not None:
                rec.state.advance(sweep)
        tr.step(sweep)
        sweep += 1
    assert tr.active, "no in-flight traffic to cancel"
    first = tr.cancel_flow(0)
    snap = [(c.bytes, dict(c.flow_bytes)) for c in tr.counters]
    second = tr.cancel_flow(0)
    assert first and second == []
    assert snap == [(c.bytes, dict(c.flow_bytes)) for c in tr.counters]
    assert not tr.flow_active(0)
